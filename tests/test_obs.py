"""Telemetry plane: metrics registry, span tracing, burn-rate blame.

Covers the invariants the observability layer promises:

  * histogram percentiles within one log bucket of the exact order
    statistic, on adversarial inputs (bucket-edge values, heavy tails);
  * histogram merge is *exact parity* with single-stream recording;
  * the simulator emits one span per served access, and along a linear
    walk the span queue+service durations plus the coordinator barrier
    sum exactly to the query's simulated latency (jitter off);
  * tail-biased sampling never drops a violating query's trace;
  * burn-rate attribution names the constructed hotspot server, both
    directly and through the adaptive controller's repair report;
  * ``TRANSFER.scope()`` isolates and restores transfer accounting;
  * ``replicate_stream``'s double-buffered ingestion provisions the
    same scheme as eager chunked deltas and reports the overlap gauge.
"""
import json

import numpy as np
import pytest

from repro import obs
from repro.core import ReplicationScheme, replicate_workload
from repro.core.paths import PathSet
from repro.distsys import Cluster, LatencyModel, execute_workload
from repro.engine import TRANSFER
from repro.obs import (
    Counter,
    Histogram,
    MetricsRegistry,
    Tracer,
    attribute_burn,
    chrome_trace,
    install_compile_hook,
)
from repro.serve import AdaptiveController, ControllerConfig, simulate
from tests.conftest import random_workload


@pytest.fixture
def obs_on():
    """Enable the plane with a clean registry; restore on exit."""
    was = obs.enabled()
    obs.REGISTRY.reset()
    obs.enable()
    try:
        yield obs.REGISTRY
    finally:
        (obs.enable if was else obs.disable)()
        obs.REGISTRY.reset()


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------
def test_registry_get_or_create_and_kind_mismatch():
    reg = MetricsRegistry()
    c = reg.counter("a.b")
    c.inc(3)
    assert reg.counter("a.b") is c          # get-or-create returns same obj
    assert reg.counter("a.b").value == 3
    reg.gauge("a.g").set(2.5)
    reg.histogram("a.h").record(10.0)
    assert reg.names() == ["a.b", "a.g", "a.h"]
    with pytest.raises(TypeError, match="already a"):
        reg.gauge("a.b")
    with pytest.raises(TypeError, match="already a"):
        reg.counter("a.h")
    snap = reg.snapshot()
    assert snap["a.b"] == 3 and snap["a.g"] == 2.5
    assert snap["a.h"]["count"] == 1
    json.dumps(snap)                        # artifact must be serializable
    reg.reset()
    assert reg.names() == []


@pytest.mark.parametrize(
    "values",
    [
        np.random.default_rng(0).lognormal(3.0, 1.5, 5000),   # heavy tail
        np.random.default_rng(1).pareto(1.5, 5000) + 1.0,     # heavier tail
        np.full(100, 42.0),                                   # degenerate
        1.0 * 1.1 ** np.arange(200),                          # exact edges
        np.concatenate([np.full(99, 1.0), [1e9]]),            # one outlier
    ],
)
def test_histogram_percentile_within_one_bucket(values):
    h = Histogram("t", lo=1.0, growth=1.1)
    h.record_many(values)
    for q in (50.0, 90.0, 99.0):
        exact = float(np.percentile(values, q, method="inverted_cdf"))
        got = h.percentile(q)
        # upper-edge convention: exact sits in the reported bucket, i.e.
        # within one multiplicative bucket width below the edge
        assert got / h.growth <= exact * (1 + 1e-9), (q, got, exact)
        assert exact <= got * (1 + 1e-9), (q, got, exact)
    assert h.n == len(values)
    assert h.max == pytest.approx(float(np.max(values)))


def test_histogram_scalar_vs_vector_recording_identical():
    vals = np.random.default_rng(2).lognormal(2.0, 1.0, 777)
    a = Histogram("a", lo=0.5, growth=1.2)
    b = Histogram("b", lo=0.5, growth=1.2)
    a.record_many(vals)
    for v in vals:
        b.record(float(v))
    assert a.counts == b.counts and a.n == b.n


def test_histogram_merge_exact_parity():
    rng = np.random.default_rng(3)
    x, y = rng.lognormal(2, 1, 400), rng.pareto(2.0, 600) + 0.1
    h1 = Histogram("h", lo=0.1, growth=1.1)
    h2 = Histogram("h", lo=0.1, growth=1.1)
    ref = Histogram("h", lo=0.1, growth=1.1)
    h1.record_many(x)
    h2.record_many(y)
    ref.record_many(np.concatenate([x, y]))
    m = h1.merge(h2)
    assert m.counts == ref.counts
    assert m.n == ref.n and m.sum == pytest.approx(ref.sum)
    for q in (50.0, 99.0, 99.9):
        assert m.percentile(q) == ref.percentile(q)  # bit-identical
    with pytest.raises(ValueError, match="geometry"):
        h1.merge(Histogram("h", lo=0.1, growth=1.2))


def test_compile_hook_counts_jit_cache_misses():
    import jax

    counter = install_compile_hook()
    assert isinstance(counter, Counter)
    before = counter.value

    @jax.jit
    def _fresh(x):
        return x * 3 + 1

    _fresh(np.arange(7))                    # cache miss: compiles
    assert counter.value >= before + 1
    mid = counter.value
    _fresh(np.arange(7))                    # cache hit: no event
    assert counter.value == mid


# ---------------------------------------------------------------------------
# span tracing (simulator)
# ---------------------------------------------------------------------------
def _traced_run(rng, rate_qps=1.0, jitter=0.0, budget=1e12, **kw):
    ps, shard = random_workload(rng, n_paths=150, n_queries=60)
    scheme, _ = replicate_workload(ps, shard, 5, t=2)
    cluster = Cluster(scheme)
    model = LatencyModel(jitter_sigma=jitter)
    tr = Tracer(budget_us=budget)
    rep = simulate(
        cluster, ps, rate_qps=rate_qps, model=model, seed=4, trace=tr, **kw
    )
    return ps, rep, tr, model


def test_one_span_per_served_access(rng):
    ps, rep, tr, _ = _traced_run(rng)
    # the access tree dedups shared prefixes: expected span count is the
    # number of unique path prefixes per query
    expected = 0
    prefixes: dict[int, set] = {}
    for p in range(ps.n_paths):
        q = int(ps.query_ids[p])
        seen = prefixes.setdefault(q, set())
        pref = ()
        for x in range(int(ps.lengths[p])):
            pref = pref + (int(ps.objects[p, x]),)
            if pref not in seen:
                seen.add(pref)
                expected += 1
    assert tr.n_spans == expected
    # near-zero load: every kept trace's spans show no queue wait
    for t in tr.traces:
        for s in t.spans:
            assert s.queue_wait_us == pytest.approx(0.0)
            assert s.server >= 0


def test_linear_walk_spans_sum_to_latency(rng):
    """Along a linear walk, queue+service spans + coordinator == latency."""
    ps, rep, tr, model = _traced_run(rng, jitter=0.0)
    checked = 0
    for t in tr.traces:
        spans = t.spans
        if not spans:
            continue
        starts = sorted(s.t_start_us for s in spans)
        ends = sorted(s.t_end_us for s in spans)
        linear = all(e <= s2 + 1e-9 for e, s2 in zip(ends[:-1], starts[1:]))
        if linear:
            total = sum(s.queue_wait_us + s.service_us for s in spans)
            assert total + model.coordinator_us == pytest.approx(
                t.latency_us
            )
            checked += 1
    assert checked > 0, "workload produced no linear walks to check"


def test_tracing_does_not_perturb_simulation(rng):
    ps, shard = random_workload(rng, n_paths=200, n_queries=80)
    scheme, _ = replicate_workload(ps, shard, 5, t=2)
    cluster = Cluster(scheme)
    rep0 = simulate(cluster, ps, rate_qps=50_000, seed=9)
    rep1 = simulate(
        cluster, ps, rate_qps=50_000, seed=9, trace=Tracer(budget_us=100.0)
    )
    assert np.array_equal(rep0.latency_us, rep1.latency_us)


def test_tail_bias_never_drops_violators(rng):
    ps, shard = random_workload(rng, n_paths=300, n_queries=120)
    scheme, _ = replicate_workload(ps, shard, 5, t=2)
    cluster = Cluster(scheme)
    rep0 = simulate(cluster, ps, rate_qps=300_000, seed=5, concurrency=4)
    p80 = float(np.percentile(rep0.latency_us, 80.0))
    # tiny head+ring so sampling pressure is real: violators must survive
    tr = Tracer(budget_us=p80, head=2, ring=4)
    rep = simulate(
        cluster, ps, rate_qps=300_000, seed=5, concurrency=4, trace=tr
    )
    violators = set(np.nonzero(rep.latency_us > p80)[0].tolist())
    assert len(violators) > 4, "need more violators than the ring holds"
    assert tr.n_violations == len(violators)
    kept = {t.query for t in tr.traces}
    assert violators <= kept
    assert all(t.violated for t in tr.violations)
    assert len(tr.traces) <= 2 + 4 + len(violators)
    # non-violators ARE sampled away under this pressure
    assert len(kept) < ps.n_queries


def test_tracer_reused_across_runs_accumulates(rng):
    ps, shard = random_workload(rng, n_paths=100, n_queries=40)
    scheme, _ = replicate_workload(ps, shard, 5, t=2)
    cluster = Cluster(scheme)
    tr = Tracer(budget_us=1e12)
    simulate(cluster, ps, rate_qps=1000, seed=1, trace=tr)
    simulate(cluster, ps, rate_qps=1000, seed=2, trace=tr)
    assert tr.n_completed == 2 * ps.n_queries


def test_chrome_trace_export(rng, tmp_path):
    _, _, tr, _ = _traced_run(rng, rate_qps=100_000)
    out = tmp_path / "trace.json"
    blob = tr.chrome_trace(str(out))
    loaded = json.loads(out.read_text())
    assert loaded == blob
    events = blob["traceEvents"]
    slices = [e for e in events if e["ph"] == "X"]
    assert slices, "no slices exported"
    for e in slices:
        assert e["dur"] >= 0 and e["ts"] >= 0
        assert {"query", "hop", "object", "why"} <= set(e["args"])
    names = {e["args"]["name"] for e in events if e["ph"] == "M"}
    assert any(n.startswith("server-") for n in names)


# ---------------------------------------------------------------------------
# structural spans (closed-form executor)
# ---------------------------------------------------------------------------
def test_executor_structural_spans(rng):
    ps, shard = random_workload(rng, n_paths=120, n_queries=50)
    scheme, _ = replicate_workload(ps, shard, 5, t=2)
    tr = Tracer()
    rep = execute_workload(Cluster(scheme), ps, LatencyModel(), seed=1,
                           trace=tr)
    assert tr.n_completed == ps.n_queries
    # same shared-prefix dedup as the simulator: span counts match
    sim_tr = Tracer()
    simulate(Cluster(scheme), ps, rate_qps=1.0, seed=1, trace=sim_tr)
    assert tr.n_spans == sim_tr.n_spans


# ---------------------------------------------------------------------------
# burn-rate attribution (the acceptance-criterion hotspot)
# ---------------------------------------------------------------------------
def _hotspot_case(rng, n_queries=120):
    """Every query walks hot(s0) -> spread(s1/s2) -> hot(s0): server 0
    serves 2/3 of all traffic and owns the queue, and the walk makes two
    distributed traversals (h=2), so a t=1 controller must repair."""
    n_obj = 30
    shard = np.zeros(n_obj, np.int32)
    shard[20:] = rng.integers(1, 3, 10)      # objects 20.. on servers 1/2
    paths = [
        [int(rng.integers(0, 10)), int(rng.integers(20, n_obj)),
         int(rng.integers(10, 20))]
        for _ in range(n_queries)
    ]
    ps = PathSet.from_lists(paths, list(range(n_queries)))
    scheme = ReplicationScheme.from_sharding(shard, 3)
    return ps, shard, scheme


def test_burn_attribution_names_hotspot_server(rng):
    ps, shard, scheme = _hotspot_case(rng)
    cluster = Cluster(scheme)
    rep0 = simulate(cluster, ps, rate_qps=400_000, seed=3, concurrency=2)
    p90 = float(np.percentile(rep0.latency_us, 90.0))
    tr = Tracer(budget_us=p90)
    rep = simulate(
        cluster, ps, rate_qps=400_000, seed=3, concurrency=2, trace=tr
    )
    assert tr.n_violations > 0
    burn = attribute_burn(tr, allowed_frac=0.01)
    tb = burn["default"]
    assert tb.n_violations == tr.n_violations
    assert tb.burn_rate > 1.0               # 10% violating >> 1% allowed
    # the acceptance check: blame names the constructed hotspot, and the
    # violators' worst hops point at it too
    assert tb.top_server() == 0
    assert tb.blame_queue_us[0] == max(tb.blame_queue_us.values())
    worst = [h.server for h in tb.worst_hops]
    assert worst and worst.count(0) >= len(worst) // 2
    # every worst hop names a hop/server/share a human can read
    for h in tb.worst_hops:
        assert 0.0 <= h.share <= 1.0 + 1e-9
        assert h.latency_us > h.budget_us


def test_controller_report_carries_blame(rng):
    """A repair triggered on the hotspot explains itself: report.blame
    names the server whose queue ate the violators' budgets."""
    ps, shard, scheme = _hotspot_case(rng)
    cluster = Cluster(scheme)
    rep0 = simulate(cluster, ps, rate_qps=400_000, seed=3, concurrency=2)
    p90 = float(np.percentile(rep0.latency_us, 90.0))
    tr = Tracer(budget_us=p90)
    rep = simulate(
        cluster, ps, rate_qps=400_000, seed=3, concurrency=2, trace=tr
    )
    controller = AdaptiveController(
        cluster, ControllerConfig(t=1, window=512, min_queries=32)
    )
    report = controller.observe(ps, latency_us=rep.latency_us, trace=tr)
    assert report is not None, "3-hop paths at t=1 must trigger a repair"
    assert report.blame is not None
    blame = report.blame["default"]
    assert blame["top_server"] == 0
    assert blame["burn_rate"] > 1.0
    # untraced observe keeps the legacy report shape
    ctl2 = AdaptiveController(
        Cluster(ReplicationScheme.from_sharding(shard, 3)),
        ControllerConfig(t=1, window=512, min_queries=32),
    )
    rep2 = ctl2.observe(ps, latency_us=rep.latency_us)
    assert rep2 is not None and rep2.blame is None


# ---------------------------------------------------------------------------
# TRANSFER.scope
# ---------------------------------------------------------------------------
def test_transfer_scope_isolates_and_restores():
    base = TRANSFER.h2d_bytes
    with TRANSFER.scope():
        TRANSFER.h2d_bytes += 100
        TRANSFER.h2d_calls += 1
        with TRANSFER.scope():              # nesting isolates each level
            assert TRANSFER.h2d_bytes == 0
            TRANSFER.h2d_bytes += 7
        assert TRANSFER.h2d_bytes == 107    # inner totals restored
    assert TRANSFER.h2d_bytes == base + 107


def test_transfer_scope_restores_on_exception():
    base = TRANSFER.h2d_bytes
    with pytest.raises(RuntimeError):
        with TRANSFER.scope():
            TRANSFER.h2d_bytes += 11
            raise RuntimeError("boom")
    assert TRANSFER.h2d_bytes == base + 11


# ---------------------------------------------------------------------------
# provisioning telemetry + pipelined streaming
# ---------------------------------------------------------------------------
def test_stream_pipeline_matches_eager_and_reports_overlap(rng, obs_on):
    from repro.core import replicate_delta, replicate_stream
    from repro.engine import LatencyEngine, PathStream

    ps, shard = random_workload(rng, n_paths=160, n_queries=80)
    chunk = 40
    chunks = [
        ps.select(np.arange(i, min(i + chunk, ps.n_paths)))
        for i in range(0, ps.n_paths, chunk)
    ]
    scheme_d = ReplicationScheme.from_sharding(shard, 5)
    eng = LatencyEngine(scheme_d)
    for c in chunks:
        replicate_delta(c, eng, 2, fused=True)
    # the eager deltas above each drained their own device stats; clear
    # the registry so the readback assertion below sees only the stream's
    obs_on.reset()

    def gen():
        yield from chunks

    scheme_s, stats = replicate_stream(
        PathStream(gen()), shard, 5, t=2, fused=True
    )
    assert np.array_equal(scheme_d.mask, scheme_s.mask)
    assert stats.ingest_overlap_s >= 0.0
    # the fused stream defers its device stats: ONE readback at the end
    snap = obs_on.snapshot()
    assert snap["repro.greedy.stat_readbacks"] == 1
    assert snap["repro.stream.chunks"] == len(chunks)
    assert "repro.stream.ingest_overlap_s" in snap
    # per-class provisioning timeline rode along
    assert stats.timeline, "obs-enabled run must carry a greedy timeline"
    for row in stats.timeline:
        assert {"budget", "n_vec", "n_seq", "n_candidates",
                "routed_skips"} <= set(row)


def test_simulator_registers_serve_metrics(rng, obs_on):
    ps, shard = random_workload(rng, n_paths=100, n_queries=40)
    scheme, _ = replicate_workload(ps, shard, 5, t=2)
    simulate(Cluster(scheme), ps, rate_qps=10_000, seed=1)
    snap = obs_on.snapshot()
    assert snap["repro.serve.queries"] == ps.n_queries
    assert snap["repro.serve.latency_us"]["count"] == ps.n_queries
    assert snap["repro.serve.latency_us"]["p99"] > 0


def test_disabled_plane_registers_nothing(rng):
    obs.disable()
    obs.REGISTRY.reset()
    ps, shard = random_workload(rng, n_paths=60, n_queries=25)
    scheme, stats = replicate_workload(ps, shard, 5, t=2)
    simulate(Cluster(scheme), ps, rate_qps=10_000, seed=1)
    # the jit compile hook is a process-global JAX listener (cannot be
    # uninstalled), so its counter may reappear; nothing else may
    assert [n for n in obs.REGISTRY.names()
            if n != "repro.jit.compiles"] == []
    assert stats.timeline is None
