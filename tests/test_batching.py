"""Batched dispatch serving plane (PR 8).

Pins the contracts of the batching/admission/hedging planes and their
harness validation:

  * **ladder** — rung selection from queue depth, straggler handling
    (a lone arrival still flushes as a batch of one after the window);
  * **batching headline** — at saturation with a real per-dispatch cost,
    batched p99 <= per-query p99 (the amortization the plane exists for);
  * **admission** — a zero remaining budget sheds at admission (fail
    fast, never queued), overload shedding improves the surviving p99,
    and burn attribution reports shed *next to* violated, not folded in;
  * **hedging** — a fired hedge whose primary wins is not double-counted:
    one completion per query, losers cancelled, wins + primary-wins =
    fired;
  * **mixed loop** — one run serving closed-loop foreground against
    open-loop background, split percentiles per loop;
  * **harness** — the asyncio wall-clock harness agrees with the
    discrete-event simulator at low load (generous test band; the
    benchmark states the tighter one) and reproduces the batching win on
    a real clock;
  * **engine** — trace_paths_batched returns row-identical traces to
    per-batch trace_paths calls (one dispatch, same walk).
"""
import numpy as np
import pytest

from repro.core import replicate_workload
from repro.core.paths import PathSet
from repro.core.slo import SLOSpec, TenantSpec
from repro.distsys import Cluster, LatencyModel
from repro.distsys.executor import trace_paths, trace_paths_batched
from repro.obs import Tracer, attribute_burn
from repro.serve import (
    AdmissionConfig,
    BatchLadder,
    BatchingConfig,
    HedgePolicy,
    harness_simulate,
    simulate,
)
from tests.conftest import random_workload


def _cluster(rng, n_paths=200, n_queries=150, t=1, max_len=5):
    ps, shard = random_workload(
        rng, n_paths=n_paths, n_queries=n_queries, max_len=max_len
    )
    scheme, _ = replicate_workload(ps, shard, 5, t=t)
    return Cluster(scheme), ps


# ---------------------------------------------------------------------------
# ladder + config units
# ---------------------------------------------------------------------------
def test_batch_ladder_pick_rungs():
    lad = BatchLadder()
    assert lad.rungs == (1, 2, 4, 8, 16)
    assert lad.pick(0) == 1      # a flush always takes at least one job
    assert lad.pick(1) == 1
    assert lad.pick(3) == 2      # largest rung <= depth
    assert lad.pick(7) == 4
    assert lad.pick(16) == 16
    assert lad.pick(1000) == 16  # capped at the top rung
    assert BatchLadder(rungs=(1, 3, 9)).pick(8) == 3


def test_batch_ladder_validation():
    with pytest.raises(ValueError):
        BatchLadder(rungs=(2, 4))       # must start at 1
    with pytest.raises(ValueError):
        BatchLadder(rungs=(1, 4, 2))    # strictly increasing
    with pytest.raises(ValueError):
        BatchLadder(rungs=())


def test_admission_config_needs_a_deadline(rng):
    cluster, ps = _cluster(rng, n_paths=40, n_queries=30)
    with pytest.raises(ValueError, match="deadline"):
        simulate(cluster, ps, rate_qps=1e3, admission=AdmissionConfig())


# ---------------------------------------------------------------------------
# batching: the amortization headline + straggler behavior
# ---------------------------------------------------------------------------
def test_batched_p99_not_worse_at_saturation(rng):
    """With a real per-dispatch cost and scarce slots, one engine dispatch
    per ladder batch must not lose to per-query dispatch at saturation."""
    cluster, ps = _cluster(rng)
    model = LatencyModel(dispatch_us=20.0)
    kw = dict(rate_qps=1e5, model=model, concurrency=2, seed=3)
    per_query = simulate(cluster, ps, **kw)
    batched = simulate(cluster, ps, batching=BatchingConfig(), **kw)
    assert batched.p99_us <= per_query.p99_us
    bs = batched.batch_stats
    assert bs is not None and bs.n_batches > 0
    assert bs.batched_jobs >= bs.n_batches
    assert bs.mean_occupancy > 1.0          # saturation actually batched
    assert 1 <= bs.max_occupancy <= 16


def test_batch_single_straggler_flushes_alone(rng):
    """A lone arrival must not wait for company: the window timer flushes
    it as a batch of one and the query completes."""
    # single-hop paths: one job per arrival, so nothing can share a batch
    ps = PathSet.from_lists(
        [[i] for i in range(30)], query_ids=list(range(30))
    )
    shard = (np.arange(30) % 5).astype(np.int32)
    scheme, _ = replicate_workload(ps, shard, 5, t=1)
    cluster = Cluster(scheme)
    # arrivals far wider than the 50 us window: every flush is a straggler
    arrivals = np.arange(ps.n_queries, dtype=np.float64) * 5e3
    rep = simulate(
        cluster, ps, arrivals_us=arrivals, batching=BatchingConfig(), seed=0
    )
    assert rep.batch_stats.max_occupancy == 1
    assert rep.batch_stats.n_batches == rep.batch_stats.batched_jobs
    # the straggler pays its own window, never an unbounded wait
    assert (rep.latency_us <= 50.0 + 100.0).all()
    assert (rep.latency_us > 0).all()
    # every batch paid the window once per flushed hop level at most; the
    # run completes with finite latencies, nothing leaks
    assert np.isfinite(rep.latency_us).all()


# ---------------------------------------------------------------------------
# deadline-aware admission
# ---------------------------------------------------------------------------
def test_zero_budget_sheds_at_admission(rng):
    """A query whose floor latency already exceeds a zero budget is shed
    at admission: failed fast, never queued, reported separately."""
    cluster, ps = _cluster(rng)
    rep = simulate(
        cluster, ps, rate_qps=1e4,
        admission=AdmissionConfig(deadline_us=0.0), seed=1,
    )
    assert rep.query_shed is not None
    assert rep.query_shed.all()             # nothing can meet a 0 us deadline
    assert rep.shed_frac == 1.0
    assert rep.surviving_latencies().size == 0
    # shed queries still complete (fail-fast response), with latencies far
    # below what serving the work would have cost
    assert np.isfinite(rep.latency_us).all()
    s = rep.summary()
    assert s["admission"]["n_shed"] == ps.n_queries
    assert s["admission"]["surviving_p99_us"] is None


def test_shedding_improves_surviving_p99_at_overload(rng):
    cluster, ps = _cluster(rng)
    slo = SLOSpec.uniform(2, ps.n_queries, p99_slo_us=400.0)
    kw = dict(rate_qps=3e5, concurrency=2, seed=5, slo=slo)
    overloaded = simulate(cluster, ps, **kw)
    shed = simulate(
        cluster, ps, admission=AdmissionConfig(stretch=4.0), **kw
    )
    assert 0.0 < shed.shed_frac < 1.0
    surv_p99 = float(np.percentile(shed.surviving_latencies(), 99.0))
    assert surv_p99 < overloaded.p99_us
    s = shed.summary()
    assert s["admission"]["per_tenant_shed_frac"]["default"] == pytest.approx(
        shed.shed_frac
    )


def test_burn_attribution_reports_shed_next_to_violated(rng):
    """attribute_burn must distinguish load shed by policy from queries
    that were served and blew their budget."""
    cluster, ps = _cluster(rng)
    slo = SLOSpec.uniform(
        2, ps.n_queries,
        tenant="gold", p99_slo_us=300.0,
    )
    tracer = Tracer(budget_us=300.0)
    rep = simulate(
        cluster, ps, rate_qps=3e5, concurrency=2, seed=5, slo=slo,
        admission=AdmissionConfig(stretch=4.0), trace=tracer,
    )
    assert rep.shed_frac > 0.0
    burn = attribute_burn(tracer, tenant_names=("gold",))
    tb = burn["gold"]
    assert tb.n_shed == int(rep.query_shed.sum())
    # a shed query never counts as a violation: the two totals partition
    assert tb.n_violations + tb.n_shed <= tb.n_queries
    assert tb.shed_frac == pytest.approx(rep.shed_frac)


# ---------------------------------------------------------------------------
# SLO-driven hedging
# ---------------------------------------------------------------------------
def test_hedge_fires_and_primary_win_not_double_counted(rng):
    cluster, ps = _cluster(rng)
    slo = SLOSpec.uniform(2, ps.n_queries)
    hedge = HedgePolicy(quantile=75.0, min_samples=32)
    rep = simulate(
        cluster, ps, rate_qps=3e4, concurrency=4, seed=7, slo=slo,
        hedge=hedge,
    )
    assert rep.slo_hedging
    assert rep.hedges_fired > 0              # the threshold learned + fired
    # exactly one completion per query regardless of who won the race
    assert rep.latency_us.shape == (ps.n_queries,)
    assert np.isfinite(rep.latency_us).all()
    assert 0 <= rep.hedge_wins <= rep.hedges_fired
    # the loser's queued work is skipped, not served: cancellations only
    # exist because hedges raced
    if rep.hedges_cancelled:
        assert rep.hedges_fired > 0
    s = rep.summary()["hedging"]
    assert s["fired"] == rep.hedges_fired
    assert s["wins"] == rep.hedge_wins
    assert 0.0 < s["hedge_frac"] <= hedge.max_hedges_frac + 1e-9


def test_hedge_threshold_learns_per_tenant():
    hp = HedgePolicy(quantile=95.0, min_samples=8)
    assert hp.threshold_us(0) is None        # no evidence yet
    for i in range(64):
        hp.observe(0, 100.0 + i)
    th = hp.threshold_us(0)
    assert th is not None and 140.0 < th < 175.0
    assert hp.threshold_us(1) is None        # tenants learn independently
    snap = hp.snapshot()
    assert 0 in snap and snap[0] == pytest.approx(th)


def test_hedge_rejects_conflicting_modes(rng):
    from repro.distsys import Router

    cluster, ps = _cluster(rng, n_paths=40, n_queries=30)
    with pytest.raises(ValueError):
        simulate(
            cluster, ps, hedge=HedgePolicy(),
            router=Router(cluster.scheme, "hedged"),
        )
    with pytest.raises(ValueError):
        simulate(cluster, ps, hedge=HedgePolicy(), hop_feedback=True)


# ---------------------------------------------------------------------------
# mixed open/closed loop
# ---------------------------------------------------------------------------
def test_mixed_loop_splits_percentiles(rng):
    cluster, ps = _cluster(rng)
    closed = np.arange(0, ps.n_queries, 3)
    rep = simulate(
        cluster, ps, rate_qps=2e4, clients=4, closed_queries=closed,
        seed=2,
    )
    assert rep.closed_mask is not None
    assert int(rep.closed_mask.sum()) == len(closed)
    s = rep.summary()
    assert s["mode"] == "mixed_loop"
    n_c = s["closed_loop_split"]["n_queries"]
    n_o = s["open_loop_split"]["n_queries"]
    assert n_c == len(closed) and n_c + n_o == ps.n_queries
    assert s["closed_loop_split"]["p99_us"] > 0
    assert s["open_loop_split"]["p99_us"] > 0


def test_mixed_loop_requires_clients(rng):
    cluster, ps = _cluster(rng, n_paths=40, n_queries=30)
    with pytest.raises(ValueError, match="clients"):
        simulate(cluster, ps, closed_queries=np.array([0, 1]))


# ---------------------------------------------------------------------------
# harness validation (real asyncio clock)
# ---------------------------------------------------------------------------
def test_harness_matches_simulator_lowload(rng):
    """Distributional agreement at low load, fixed seed.  The benchmark
    states the <= 15% band on its bigger run; the test band is generous
    because CI wall clocks are noisy and the run is kept short."""
    cluster, ps = _cluster(rng)
    kw = dict(rate_qps=2e4, concurrency=32, seed=11)
    sim = simulate(cluster, ps, **kw)
    har = harness_simulate(cluster, ps, time_scale=5e-4, **kw)
    assert har.latency_us.shape == sim.latency_us.shape
    for q in (50.0, 99.0):
        s, h = sim.percentile(q), har.percentile(q)
        assert abs(h - s) / s < 0.25, (q, s, h)


def test_harness_batched_beats_per_query_on_real_clock(rng):
    cluster, ps = _cluster(rng)
    model = LatencyModel(dispatch_us=20.0)
    kw = dict(rate_qps=1e5, model=model, concurrency=2, seed=3)
    per_query = harness_simulate(cluster, ps, time_scale=2e-4, **kw)
    batched = harness_simulate(
        cluster, ps, time_scale=2e-4, batching=BatchingConfig(), **kw
    )
    assert batched.p99_us < per_query.p99_us
    assert batched.batch_stats.mean_occupancy > 1.0


# ---------------------------------------------------------------------------
# engine: one dispatch for many batches
# ---------------------------------------------------------------------------
def test_trace_paths_batched_row_identity(rng):
    ps, shard = random_workload(rng, n_paths=90, n_queries=60)
    scheme, _ = replicate_workload(ps, shard, 5, t=1)
    alive = np.ones(5, bool)
    rb = np.random.default_rng(9)
    idx = rb.permutation(ps.n_paths)
    batches = []
    for lo in range(0, ps.n_paths, 17):
        sub = idx[lo:lo + 17]
        start = (
            rb.integers(0, 5, len(sub)).astype(np.int32)
            if lo % 2 == 0 else None
        )
        batches.append((sub, start))
    outs = trace_paths_batched(ps, scheme, alive, batches)
    assert len(outs) == len(batches)
    for (sub, start), (srv_b, loc_b) in zip(batches, outs):
        sel = ps.select(np.asarray(sub))
        srv_1, loc_1 = trace_paths(
            scheme=scheme, alive=alive, pathset=sel,
            start=None if start is None else start,
        )
        L = srv_1.shape[1]
        assert np.array_equal(srv_b[:, :L], srv_1)
        assert np.array_equal(loc_b[:, :L], loc_1)
