"""Multi-device behaviors under a small fake mesh (subprocess-isolated so
the 8-device XLA flag never leaks into other tests)."""
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # subprocess + 8-device XLA compile: minutes

SCRIPT_AGG = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models import gnn as G

mesh = jax.make_mesh((4, 2), ("data", "model"))
rng = np.random.default_rng(0)
N, E, d = 64, 256, 16
msgs = jnp.asarray(rng.normal(size=(E, d)), jnp.float32)
recv = jnp.asarray(rng.integers(0, N, E), jnp.int32)

cfg = G.GNNConfig(agg_axes=("data", "model"), node_axes=("data",))
agg = G.make_agg(cfg)

def run(kind):
    with jax.set_mesh(mesh):
        f = jax.jit(lambda m, r: agg(m, r, N, kind),
                    in_shardings=(NamedSharding(mesh, P(("data","model"), None)),
                                  NamedSharding(mesh, P(("data","model")))))
        return np.asarray(f(msgs, recv))

for kind in ("sum", "mean"):
    got = run(kind)
    want = np.asarray(G._agg_dense(msgs, recv, N, kind))
    assert np.allclose(got, want, atol=1e-5), (kind, np.abs(got-want).max())
print("AGG_OK")
"""

SCRIPT_LM = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models import transformer as T
from repro.optim import AdamW

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = T.TransformerConfig(n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                          d_ff=64, vocab=64, dtype=jnp.float32, remat=True,
                          remat_block=2, loss_chunk=16,
                          act_dp=("data",), act_tp="model", act_seq=True,
                          tp_size=2)
opt = AdamW(lr=1e-3)
pspecs = T.param_specs(cfg, ("data",), "model", 2, 4)
named = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                               is_leaf=lambda x: isinstance(x, P))

def step(params, batch):
    return T.loss_fn(params, batch["tokens"], batch["labels"], cfg)

params = T.init(cfg, jax.random.key(0))
toks = jax.random.randint(jax.random.key(1), (8, 16), 0, 64)
batch = {"tokens": toks, "labels": toks}
with jax.set_mesh(mesh):
    sharded = jax.device_put(params, named(pspecs))
    loss_sharded = jax.jit(step, in_shardings=(named(pspecs), None))(
        sharded, batch)
# reference on a single logical device layout
cfg0 = dataclasses.replace(cfg, act_dp=(), act_seq=False)
loss_plain = T.loss_fn(params, toks, toks, cfg0)
assert abs(float(loss_sharded) - float(loss_plain)) < 1e-3, (
    float(loss_sharded), float(loss_plain))
print("LM_OK")
"""


def _run(script: str, marker: str):
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=420,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root"})
    assert marker in out.stdout, out.stdout + out.stderr


def test_shard_map_aggregation_matches_dense():
    """shard_map partial-sum + psum_scatter == plain segment_sum."""
    _run(SCRIPT_AGG, "AGG_OK")


def test_sharded_lm_loss_matches_unsharded():
    """FSDP + act constraints + seq-sharded carries + chunked loss compute
    the same loss as the plain single-device path."""
    _run(SCRIPT_LM, "LM_OK")
