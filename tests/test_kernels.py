"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PathSet, ReplicationScheme, path_latencies
from repro.kernels import ops
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.embedding_bag import embedding_bag_pallas
from repro.kernels.path_latency import path_latency_pallas
from repro.kernels.ref import (
    decode_attention_ref,
    embedding_bag_ref,
    path_latency_ref,
)


@pytest.mark.parametrize("n_srv", [3, 32, 40, 70])
@pytest.mark.parametrize("n_paths", [1, 100, 257])
def test_path_latency_vs_core(n_srv, n_paths, rng):
    n_obj = 200
    shard = rng.integers(0, n_srv, n_obj).astype(np.int32)
    scheme = ReplicationScheme.from_sharding(shard, n_srv)
    extra = rng.integers(0, n_obj, 300)
    extra_s = rng.integers(0, n_srv, 300)
    scheme.mask[extra, extra_s] = True
    ps = PathSet.from_lists(
        [rng.integers(0, n_obj, rng.integers(1, 9)).tolist()
         for _ in range(n_paths)])
    got = ops.path_latency(ps, scheme)
    want = path_latencies(ps, scheme)
    assert np.array_equal(got, want)


def test_path_latency_ref_equals_kernel(rng):
    P, L, W, S = 64, 6, 2, 50
    home = rng.integers(0, S, (P, L)).astype(np.int32)
    masks = rng.integers(0, 2**32, (P, L, W), dtype=np.uint32)
    lengths = rng.integers(1, L + 1, P).astype(np.int32)
    got = path_latency_pallas(jnp.asarray(home), jnp.asarray(masks),
                              jnp.asarray(lengths), interpret=True)
    want = path_latency_ref(jnp.asarray(home), jnp.asarray(masks),
                            jnp.asarray(lengths))
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,KV,G,hd,T,bt", [
    (2, 2, 4, 64, 300, 128),
    (1, 1, 8, 128, 1024, 256),
    (3, 4, 1, 64, 77, 64),
])
def test_decode_attention_sweep(B, KV, G, hd, T, bt, dtype, rng):
    q = jnp.asarray(rng.normal(size=(B, KV, G, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, T, KV, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, T, KV, hd)), dtype)
    lens = jnp.asarray(rng.integers(1, T + 1, B), jnp.int32)
    got = decode_attention_pallas(q, k, v, lens, block_t=bt, interpret=True)
    want = decode_attention_ref(q, k, v, lens)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol)


@pytest.mark.parametrize("mode", ["mean", "sum"])
@pytest.mark.parametrize("B,L,N,d", [(4, 3, 50, 16), (16, 7, 500, 32),
                                     (1, 1, 10, 8)])
def test_embedding_bag_sweep(B, L, N, d, mode, rng):
    table = jnp.asarray(rng.normal(size=(N, d)), jnp.float32)
    ids = jnp.asarray(rng.integers(-1, N, (B, L)), jnp.int32)
    got = embedding_bag_pallas(table, ids, mode=mode, interpret=True)
    want = embedding_bag_ref(table, ids, mode)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_embedding_bag_all_padding(rng):
    table = jnp.asarray(rng.normal(size=(10, 4)), jnp.float32)
    ids = jnp.full((2, 3), -1, jnp.int32)
    got = embedding_bag_pallas(table, ids, mode="mean", interpret=True)
    assert np.allclose(np.asarray(got), 0.0)


def test_decode_attention_matches_model_decode(rng):
    """Kernel agrees with the model's jnp decode attention path."""
    from repro.models import transformer as T

    cfg = T.TransformerConfig(n_layers=1, d_model=32, n_heads=4,
                              n_kv_heads=2, d_ff=64, vocab=50,
                              dtype=jnp.float32, remat=False)
    B, S = 2, 12
    params = T.init(cfg, __import__("jax").random.key(0))
    toks = jnp.asarray(rng.integers(0, 50, (B, S)), jnp.int32)
    cache, _ = T.prefill(params, toks, cfg, max_len=16)
    # one decode step via the model
    new_cache, logits = T.decode_step(params, cache,
                                      jnp.asarray([1, 2]), cfg)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,KV,G,hd,bq,bk,win", [
    (2, 256, 2, 4, 64, 64, 64, 0),
    (1, 128, 1, 8, 32, 32, 64, 0),
    (2, 256, 4, 2, 64, 128, 64, 48),
])
def test_flash_prefill_sweep(B, S, KV, G, hd, bq, bk, win, dtype, rng):
    from repro.kernels.flash_prefill import flash_prefill_pallas
    from repro.kernels.ref import flash_prefill_ref

    q = jnp.asarray(rng.normal(size=(B, S, KV, G, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), dtype)
    got = flash_prefill_pallas(q, k, v, block_q=bq, block_k=bk,
                               window=win, interpret=True)
    want = flash_prefill_ref(q, k, v, win)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_flash_prefill_matches_model_attention(rng):
    """Kernel == the model's jnp attention path (causal, GQA)."""
    from repro.kernels.flash_prefill import flash_prefill_pallas
    from repro.models.transformer import attention

    B, S, KV, G, hd = 2, 128, 2, 2, 32
    q5 = jnp.asarray(rng.normal(size=(B, S, KV, G, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    got = flash_prefill_pallas(q5, k, v, block_q=64, block_k=64,
                               interpret=True)
    pos = jnp.arange(S)
    want = attention(q5.reshape(B, S, KV * G, hd), k, v, pos, pos)
    np.testing.assert_allclose(
        np.asarray(got.reshape(B, S, KV * G, hd)), np.asarray(want),
        atol=2e-4, rtol=2e-4)


def test_flash_prefill_inside_model_forward(rng):
    """cfg.use_flash_prefill swaps the attention op without changing the
    model's outputs (dense + SWA)."""
    import dataclasses

    from repro.models import transformer as T

    toks = jnp.asarray(rng.integers(0, 97, (2, 128)), jnp.int32)
    for extra in ({"n_kv_heads": 2},
                  {"sliding_window": 32, "n_kv_heads": 4}):
        cfg = T.TransformerConfig(
            n_layers=2, d_model=64, n_heads=4, d_ff=128,
            vocab=97, dtype=jnp.float32, remat=False, **extra)
        cfg_f = dataclasses.replace(cfg, use_flash_prefill=True)
        params = T.init(cfg, __import__("jax").random.key(0))
        a = T.forward(params, toks, cfg)
        b = T.forward(params, toks, cfg_f)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)
