"""Core correctness: paths, latency evaluation, greedy vs exact."""
import numpy as np
import pytest

from repro.core import (
    PathSet,
    ReplicationScheme,
    is_latency_feasible,
    path_latencies,
    path_latency_reference,
    query_latencies,
    replicate_workload,
    replicate_workload_exact,
    server_local_subpaths,
    subpath_structure,
    update_exact,
)
from tests.conftest import random_workload


def test_pathset_roundtrip():
    ps = PathSet.from_lists([[1, 2, 3], [4], [5, 6]])
    assert ps.n_paths == 3
    assert ps.path(0) == [1, 2, 3]
    assert ps.path(1) == [4]
    assert ps.lengths.tolist() == [3, 1, 2]


def test_pathset_prune_redundant():
    # same tail, roots on the same server -> prunable (paper §5.3)
    shard = np.asarray([0, 0, 1, 1], dtype=np.int32)
    ps = PathSet.from_lists([[0, 2, 3], [1, 2, 3], [2, 3, 0]])
    pruned = ps.prune_redundant(shard)
    assert pruned.n_paths == 2  # first two merge (roots 0,1 both on s0)


def test_subpath_structure_matches_reference(rng):
    ps, shard = random_workload(rng)
    import jax.numpy as jnp

    home, seg, h = subpath_structure(
        jnp.asarray(ps.objects), jnp.asarray(ps.lengths), jnp.asarray(shard))
    h = np.asarray(h)
    for i in range(ps.n_paths):
        groups = server_local_subpaths(ps.path(i), shard)
        assert h[i] == len(groups) - 1, f"path {i}"


def test_latency_matches_python_oracle(rng):
    ps, shard = random_workload(rng)
    scheme = ReplicationScheme.from_sharding(shard, 5)
    extra_v = rng.integers(0, 120, 200)
    extra_s = rng.integers(0, 5, 200)
    scheme.mask[extra_v, extra_s] = True
    got = path_latencies(ps, scheme)
    for i in range(ps.n_paths):
        want = path_latency_reference(ps.path(i), scheme.mask, shard)
        assert got[i] == want, f"path {i}"


@pytest.mark.parametrize("t", [0, 1, 2, 3])
def test_greedy_exact_feasible(rng, t):
    ps, shard = random_workload(rng)
    scheme, stats = replicate_workload_exact(ps, shard, 5, t)
    assert is_latency_feasible(ps, scheme, t)
    assert stats["failed_paths"] == 0


@pytest.mark.parametrize("t", [0, 1, 2, 3])
def test_greedy_vectorized_feasible(rng, t):
    ps, shard = random_workload(rng)
    scheme, stats = replicate_workload(ps, shard, 5, t)
    assert is_latency_feasible(ps, scheme, t)
    assert stats.failed_paths == 0


def _cost_close_to_exact(rng, n_paths):
    ps, shard = random_workload(rng, n_paths=n_paths)
    for t in (1, 2):
        _, sv = replicate_workload(ps, shard, 5, t, batch_size=64)
        _, se = replicate_workload_exact(ps, shard, 5, t)
        assert sv.replicas >= se["replicas"] * 0.95
        assert sv.replicas <= se["replicas"] * 1.35


def test_vectorized_cost_close_to_exact(rng):
    """Batched (lock-free-analogue) additions may cost slightly more than
    strictly sequential ones, never less, and stay within a small factor."""
    _cost_close_to_exact(rng, n_paths=120)


@pytest.mark.slow
def test_vectorized_cost_close_to_exact_full(rng):
    """Full-size variant: more batch-collision opportunities."""
    _cost_close_to_exact(rng, n_paths=200)


def test_update_exact_no_op_when_within_bound():
    shard = np.asarray([0, 0, 0], np.int32)
    scheme = ReplicationScheme.from_sharding(shard, 2)
    res = update_exact(scheme, [0, 1, 2], t=1)
    assert res.feasible and res.cost == 0 and not res.additions


def test_update_exact_single_merge():
    # path crosses 0 -> 1 -> 0; t=1 requires merging one subpath
    shard = np.asarray([0, 1, 0], np.int32)
    scheme = ReplicationScheme.from_sharding(shard, 2)
    res = update_exact(scheme, [0, 1, 2], t=1)
    assert res.feasible
    lat = path_latency_reference([0, 1, 2], scheme.mask, shard)
    assert lat <= 1


def test_storage_capacity_rejects():
    shard = np.asarray([0, 1, 0, 1], np.int32)
    scheme = ReplicationScheme.from_sharding(shard, 2)
    # capacity equals current load -> no replica can be added
    res = update_exact(scheme, [0, 1, 2, 3], t=0, capacity=2.0)
    assert not res.feasible


def test_query_latency_is_max_over_paths(rng):
    ps = PathSet.from_lists([[0, 1], [0, 1, 2]], query_ids=[0, 0])
    shard = np.asarray([0, 1, 0], np.int32)
    scheme = ReplicationScheme.from_sharding(shard, 2)
    lq = query_latencies(ps, scheme)
    assert lq.tolist() == [2]


def test_replication_overhead_accounting():
    shard = np.zeros(4, np.int32)
    scheme = ReplicationScheme.from_sharding(shard, 2)
    assert scheme.replication_overhead() == 0.0
    scheme.mask[0, 1] = True
    assert scheme.replication_overhead() == pytest.approx(0.25)
    f = np.asarray([10.0, 1.0, 1.0, 1.0])
    assert scheme.replication_overhead(f) == pytest.approx(10.0 / 13.0)


def test_pack_bit_layout():
    shard = np.zeros(3, np.int32)
    scheme = ReplicationScheme.from_sharding(shard, 40)
    scheme.mask[1, 39] = True
    packed = scheme.pack()
    assert packed.shape == (3, 2)
    assert packed[1, 1] == np.uint32(1 << 7)  # server 39 = word 1 bit 7
    assert packed[0, 0] == np.uint32(1)       # original at server 0
