"""Distsys: executor latency model, router, checkpoints, fault schedule."""
import tempfile

import numpy as np
import pytest

from repro.core import ReplicationScheme, replicate_workload
from repro.distsys import (
    CheckpointManager,
    Cluster,
    LatencyModel,
    Router,
    execute_workload,
)
from tests.conftest import random_workload


def test_latency_grows_with_traversals(rng):
    """Fig 2a/6: mean and p99 latency grow ~linearly with t."""
    ps, shard = random_workload(rng, n_paths=400)
    means, p99s = [], []
    for t in (0, 1, 3):
        scheme, _ = replicate_workload(ps, shard, 5, t)
        rep = execute_workload(Cluster(scheme), ps, LatencyModel(), seed=1)
        means.append(rep.mean_us)
        p99s.append(rep.p99_us)
    assert means[0] < means[1] < means[2]
    assert p99s[0] < p99s[2]


def test_executor_traversals_match_core(rng):
    from repro.core import path_latencies

    ps, shard = random_workload(rng)
    scheme, _ = replicate_workload(ps, shard, 5, t=1)
    rep = execute_workload(Cluster(scheme), ps, seed=0)
    core = path_latencies(ps, scheme)
    # per-query max must agree
    want = np.zeros(ps.n_queries, np.int64)
    np.maximum.at(want, ps.query_ids, core)
    assert np.array_equal(rep.query_traversals, want)


def test_failover_degrades_but_serves(rng):
    ps, shard = random_workload(rng)
    scheme, _ = replicate_workload(ps, shard, 5, t=0)
    cl = Cluster(scheme)
    cl.fail_server(2)
    rep = execute_workload(cl, ps, seed=0)
    assert np.isfinite(rep.query_latency_us).all()


def test_hedging_reduces_tail(rng):
    ps, shard = random_workload(rng, n_paths=500)
    scheme, _ = replicate_workload(ps, shard, 5, t=1)
    base = execute_workload(Cluster(scheme), ps, seed=3)
    hedged = execute_workload(Cluster(scheme), ps, seed=3,
                              hedge_replicas=True)
    assert hedged.p99_us <= base.p99_us * 1.02


def test_router_policies(rng):
    ps, shard = random_workload(rng)
    scheme, _ = replicate_workload(ps, shard, 5, t=0)
    roots = np.maximum(ps.objects[:, 0], 0)
    r_home = Router(scheme, "home").route_roots(roots)
    assert np.array_equal(r_home, shard[roots])
    r_lb = Router(scheme, "replica_lb").route_roots(roots)
    # load-balanced routing only picks servers holding a copy
    for root, srv in zip(roots, r_lb):
        assert scheme.mask[root, srv]


def test_router_failover():
    shard = np.asarray([0], np.int32)
    scheme = ReplicationScheme.from_sharding(shard, 3)
    scheme.mask[0, 2] = True
    alive = np.asarray([False, True, True])
    out = Router(scheme, "home").route_roots(np.asarray([0]), alive)
    assert out[0] == 2


def test_checkpoint_roundtrip_and_retention():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        tree = {"w": np.arange(6, dtype=np.float32), "b": np.zeros(2)}
        for step in (1, 2, 3):
            mgr.save(step, tree)
        assert mgr.all_steps() == [2, 3]
        got, step = mgr.restore_latest(tree)
        assert step == 3
        assert np.array_equal(got["w"], tree["w"])


def test_checkpoint_async_then_restore():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        tree = {"w": np.random.default_rng(0).normal(size=(32, 8))}
        mgr.save_async(7, tree)
        mgr.wait()
        got, step = mgr.restore_latest(tree)
        assert step == 7 and np.allclose(got["w"], tree["w"])


def test_checkpoint_corruption_detected():
    import os

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, {"w": np.ones(4, np.float32)})
        # truncate the array file
        path = os.path.join(d, "step_1", "arrays.npz")
        data = open(path, "rb").read()
        open(path, "wb").write(data[: len(data) // 2])
        with pytest.raises(Exception):
            mgr.restore(1, {"w": np.ones(4, np.float32)})
