"""Distsys: executor latency model, router, checkpoints, fault schedule."""
import tempfile

import numpy as np
import pytest

from repro.core import ReplicationScheme, replicate_workload
from repro.distsys import (
    CheckpointManager,
    Cluster,
    LatencyModel,
    Router,
    execute_workload,
)
from tests.conftest import random_workload


def test_latency_grows_with_traversals(rng):
    """Fig 2a/6: mean and p99 latency grow ~linearly with t."""
    ps, shard = random_workload(rng, n_paths=400)
    means, p99s = [], []
    for t in (0, 1, 3):
        scheme, _ = replicate_workload(ps, shard, 5, t)
        rep = execute_workload(Cluster(scheme), ps, LatencyModel(), seed=1)
        means.append(rep.mean_us)
        p99s.append(rep.p99_us)
    assert means[0] < means[1] < means[2]
    assert p99s[0] < p99s[2]


def test_executor_traversals_match_core(rng):
    from repro.core import path_latencies

    ps, shard = random_workload(rng)
    scheme, _ = replicate_workload(ps, shard, 5, t=1)
    rep = execute_workload(Cluster(scheme), ps, seed=0)
    core = path_latencies(ps, scheme)
    # per-query max must agree
    want = np.zeros(ps.n_queries, np.int64)
    np.maximum.at(want, ps.query_ids, core)
    assert np.array_equal(rep.query_traversals, want)


def test_failover_degrades_but_serves(rng):
    ps, shard = random_workload(rng)
    scheme, _ = replicate_workload(ps, shard, 5, t=0)
    cl = Cluster(scheme)
    cl.fail_server(2)
    rep = execute_workload(cl, ps, seed=0)
    assert np.isfinite(rep.query_latency_us).all()


def test_hedging_reduces_tail(rng):
    ps, shard = random_workload(rng, n_paths=500)
    scheme, _ = replicate_workload(ps, shard, 5, t=1)
    base = execute_workload(Cluster(scheme), ps, seed=3)
    hedged = execute_workload(Cluster(scheme), ps, seed=3,
                              hedge_replicas=True)
    assert hedged.p99_us <= base.p99_us * 1.02


def test_router_policies(rng):
    ps, shard = random_workload(rng)
    scheme, _ = replicate_workload(ps, shard, 5, t=0)
    roots = np.maximum(ps.objects[:, 0], 0)
    r_home = Router(scheme, "home").route_roots(roots)
    assert np.array_equal(r_home, shard[roots])
    r_lb = Router(scheme, "replica_lb").route_roots(roots)
    # load-balanced routing only picks servers holding a copy
    for root, srv in zip(roots, r_lb):
        assert scheme.mask[root, srv]


def test_router_failover():
    shard = np.asarray([0], np.int32)
    scheme = ReplicationScheme.from_sharding(shard, 3)
    scheme.mask[0, 2] = True
    alive = np.asarray([False, True, True])
    out = Router(scheme, "home").route_roots(np.asarray([0]), alive)
    assert out[0] == 2


def test_router_failover_no_live_replica():
    """Dead home and no alive copy anywhere must route to -1, not crash."""
    shard = np.asarray([0, 1], np.int32)
    scheme = ReplicationScheme.from_sharding(shard, 3)
    scheme.mask[1, 2] = True  # object 1 has a backup copy; object 0 doesn't
    alive = np.asarray([False, True, True])
    roots = np.asarray([0, 1])
    for policy in ("home", "replica_lb", "hedged"):
        out = Router(scheme, policy).route_roots(roots, alive)
        assert out[0] == -1          # dead home, no live replica
        assert out[1] in (1, 2)      # dead home, live replica -> fail-over
    primary, backup = Router(scheme, "hedged").route_roots_hedged(roots, alive)
    assert primary[0] == -1 and backup[0] == -1
    assert primary[1] in (1, 2)


def test_router_hedged_primary_backup_distinct():
    shard = np.asarray([0, 0], np.int32)
    scheme = ReplicationScheme.from_sharding(shard, 3)
    scheme.mask[0, 1] = True     # object 0: copies at {0, 1}
    roots = np.asarray([0, 1])   # object 1: single copy at 0
    primary, backup = Router(scheme, "hedged").route_roots_hedged(roots)
    assert backup[0] >= 0 and backup[0] != primary[0]
    assert scheme.mask[0, primary[0]] and scheme.mask[0, backup[0]]
    assert backup[1] == -1       # nothing to hedge against


def test_route_hop_queue_aware_skips_hot_replica():
    """Eqn 1 remote-hop tie-break: live queue depth picks the replica."""
    shard = np.asarray([0], np.int32)
    scheme = ReplicationScheme.from_sharding(shard, 3)
    scheme.mask[0, 2] = True  # copies at {0 (home), 2}
    r = Router(scheme)
    # remote hop from server 1 (no local copy): Eqn 1 default goes home
    assert r.route_hop(0, 1) == (0, True)
    # the home server is hot (deep queue) -> the idle replica serves it
    hot_home = np.asarray([10, 0, 0])
    assert r.route_hop(0, 1, load=hot_home) == (2, True)
    # the replica is the hot one -> stay with the home server
    hot_replica = np.asarray([0, 0, 10])
    assert r.route_hop(0, 1, load=hot_replica) == (0, True)
    # tie -> home wins (deterministic, matches the unloaded Eqn 1 pick)
    assert r.route_hop(0, 1, load=np.zeros(3)) == (0, True)
    # a local copy always short-circuits, load or not
    assert r.route_hop(0, 2, load=hot_replica) == (2, False)
    # liveness still filters: dead replica can't serve the hop
    alive = np.asarray([True, True, False])
    assert r.route_hop(0, 1, alive=alive, load=hot_home) == (0, True)
    # nobody alive holds a copy -> -1 sentinel
    assert r.route_hop(0, 1, alive=np.asarray([False, True, False]),
                       load=hot_home) == (-1, True)


def test_executor_surfaces_failed_queries():
    """Object with no alive copy: failed query reported, run completes."""
    from repro.core.paths import PathSet

    shard = np.asarray([0, 1, 1], np.int32)
    scheme = ReplicationScheme.from_sharding(shard, 2)
    ps = PathSet.from_lists([[0, 1], [1, 2]])  # query 0 needs server 0
    cl = Cluster(scheme)
    cl.fail_server(0)
    rep = execute_workload(cl, ps, seed=0)
    assert rep.query_failed is not None
    assert bool(rep.query_failed[0])       # root had no alive copy
    assert not bool(rep.query_failed[1])   # fully on the alive server
    assert rep.n_failed == 1
    assert np.isfinite(rep.query_latency_us).all()
    assert rep.summary()["failed_queries"] == 1


def test_executor_hedged_router_min_completion(rng):
    ps, shard = random_workload(rng, n_paths=300)
    scheme, _ = replicate_workload(ps, shard, 5, t=0)
    cl = Cluster(scheme)
    base = execute_workload(cl, ps, seed=5)
    hedged = execute_workload(cl, ps, seed=5, router=Router(scheme, "hedged"))
    # min-of-two completions can only help the tail (same latency model)
    assert hedged.p99_us <= base.p99_us * 1.05
    assert np.isfinite(hedged.query_latency_us).all()


def test_checkpoint_roundtrip_and_retention():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        tree = {"w": np.arange(6, dtype=np.float32), "b": np.zeros(2)}
        for step in (1, 2, 3):
            mgr.save(step, tree)
        assert mgr.all_steps() == [2, 3]
        got, step = mgr.restore_latest(tree)
        assert step == 3
        assert np.array_equal(got["w"], tree["w"])


def test_checkpoint_async_then_restore():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        tree = {"w": np.random.default_rng(0).normal(size=(32, 8))}
        mgr.save_async(7, tree)
        mgr.wait()
        got, step = mgr.restore_latest(tree)
        assert step == 7 and np.allclose(got["w"], tree["w"])


def test_checkpoint_corruption_detected():
    import os

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, {"w": np.ones(4, np.float32)})
        # truncate the array file
        path = os.path.join(d, "step_1", "arrays.npz")
        data = open(path, "rb").read()
        open(path, "wb").write(data[: len(data) // 2])
        with pytest.raises(Exception):
            mgr.restore(1, {"w": np.ones(4, np.float32)})
