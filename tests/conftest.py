"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see the real
host device count (the 512-device override belongs to dryrun.py only)."""
import numpy as np
import pytest

from repro.core.paths import PathSet


def pytest_addoption(parser):
    parser.addoption(
        "--runslow", action="store_true", default=False,
        help="run tests marked slow (full-size / compile-heavy problems)",
    )


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running test (full-size / compile-heavy problem); "
        "skipped unless --runslow is given, keeping tier-1 fast",
    )


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip = pytest.mark.skip(reason="slow test: pass --runslow to run")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)


@pytest.fixture(autouse=True)
def _transfer_scope():
    """Scope the engine's global transfer accounting to each test.

    ``repro.engine.TRANSFER`` is process-global; ``scope()`` zeroes the
    counters on entry — so a test asserting on h2d/d2h byte counts sees
    only its own traffic — and restores outer + inner totals on exit, so
    nothing outside the test loses its accounting.
    """
    from repro.engine import TRANSFER

    with TRANSFER.scope():
        yield


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def random_workload(rng, n_obj=120, n_srv=5, n_paths=150, max_len=7,
                    n_queries=None):
    paths = [
        rng.integers(0, n_obj, rng.integers(1, max_len + 1)).tolist()
        for _ in range(n_paths)
    ]
    qids = None
    if n_queries:
        qids = rng.integers(0, n_queries, n_paths).tolist()
        qids = sorted(qids)
    shard = rng.integers(0, n_srv, n_obj).astype(np.int32)
    return PathSet.from_lists(paths, qids), shard


@pytest.fixture
def workload(rng):
    return random_workload(rng)
