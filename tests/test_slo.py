"""Per-tenant SLOs: SLOSpec plumbing, vector-t greedy/engine, arbitration."""
import numpy as np
import pytest

from repro.core import (
    PathSet,
    SLOSpec,
    TenantSpec,
    is_latency_feasible,
    query_slacks,
    replicate_workload,
)
from repro.distsys import Cluster
from repro.engine import LatencyEngine
from repro.serve import AdaptiveController, ControllerConfig
from tests.conftest import random_workload


# ---------------------------------------------------------------------------
# SLOSpec plumbing
# ---------------------------------------------------------------------------
def test_slospec_uniform_and_scalar():
    slo = SLOSpec.uniform(2, 5)
    assert slo.is_uniform and slo.scalar() == 2
    assert slo.t_q.tolist() == [2] * 5
    assert slo.tenants[0].name == "default"


def test_slospec_from_tenants_and_queries():
    tenants = (TenantSpec("a", 1), TenantSpec("b", 3))
    slo = SLOSpec.from_tenants(tenants, np.asarray([0, 1, 1, 0]))
    assert slo.t_q.tolist() == [1, 3, 3, 1]
    assert not slo.is_uniform
    assert slo.tenant_queries("b").tolist() == [1, 2]
    with pytest.raises(ValueError):
        slo.scalar()


def test_slospec_concat_merges_tenants_by_name():
    a = SLOSpec.uniform(1, 2, tenant="x")
    b = SLOSpec.uniform(2, 3, tenant="y")
    c = SLOSpec.uniform(1, 1, tenant="x")
    cat = SLOSpec.concat([a, b, c])
    assert cat.n_queries == 6
    assert [t.name for t in cat.tenants] == ["x", "y"]
    assert cat.tenant_of.tolist() == [0, 0, 1, 1, 1, 0]
    sliced = cat.select_queries(2, 5)
    assert sliced.t_q.tolist() == [2, 2, 2]


def test_slospec_align_to_pathless_tail():
    """A slice whose trailing queries have no paths must re-align before
    pairing with PathSet.concatenate (its offsets use the pathset count)."""
    # queries 0,1 have paths; query 2 produced none
    ps = PathSet.from_lists([[0, 1], [2, 3]], query_ids=[0, 1])
    slo = SLOSpec.uniform(1, 3, tenant="x")
    assert slo.align_to(ps).n_queries == ps.n_queries == 2
    other = PathSet.from_lists([[4]], query_ids=[0])
    cat_ps = PathSet.concatenate([ps, other])
    cat_slo = SLOSpec.concat(
        [slo.align_to(ps), SLOSpec.uniform(2, 1, tenant="y")]
    )
    assert cat_slo.n_queries == cat_ps.n_queries
    assert cat_slo.t_q.tolist() == [1, 1, 2]
    with pytest.raises(ValueError):
        SLOSpec.uniform(1, 1).align_to(ps)  # spec shorter than pathset


def test_path_budgets_follow_query_ids():
    ps = PathSet.from_lists([[0], [1], [2]], query_ids=[0, 0, 1])
    slo = SLOSpec(
        np.asarray([1, 4]), np.asarray([0, 0]), (TenantSpec("d", 1),)
    )
    assert slo.path_budgets(ps).tolist() == [1, 1, 4]


# ---------------------------------------------------------------------------
# greedy: scalar-vs-vector parity + genuine vector behavior
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("t", [0, 1, 2])
def test_greedy_scalar_vector_mask_equality(rng, t):
    ps, shard = random_workload(rng, n_paths=150, n_queries=90)
    a, sa = replicate_workload(ps, shard, 5, t)
    b, sb = replicate_workload(ps, shard, 5, SLOSpec.uniform(t, ps.n_queries))
    assert np.array_equal(a.mask, b.mask)
    assert sa.replicas == sb.replicas
    assert sa.total_cost == sb.total_cost


def test_greedy_vector_budgets_feasible_per_query(rng):
    ps, shard = random_workload(rng, n_paths=200, n_queries=120)
    t_q = rng.integers(0, 4, ps.n_queries).astype(np.int32)
    scheme, stats = replicate_workload(ps, shard, 5, t_q)
    assert stats.failed_paths == 0
    assert is_latency_feasible(ps, scheme, t_q)
    # slack is per query against each query's own budget
    slack = query_slacks(ps, scheme, t_q)
    assert (slack >= 0).all()


def test_greedy_vector_cheaper_than_uniform_tightest(rng):
    """Mixed budgets must not cost more than clamping everyone to the
    tightest one (the scalar workaround SLOSpec replaces)."""
    ps, shard = random_workload(rng, n_paths=200, n_queries=120)
    t_q = np.where(np.arange(ps.n_queries) % 2 == 0, 1, 3).astype(np.int32)
    mixed, _ = replicate_workload(ps, shard, 5, t_q)
    tight, _ = replicate_workload(ps, shard, 5, 1)
    assert mixed.replica_count() <= tight.replica_count()


def test_budget_aware_pruning_keeps_tight_duplicate():
    """Two identical paths with different budgets must BOTH bind: pruning
    must not merge the tight-budget path into the loose-budget one."""
    shard = np.asarray([0, 1, 2, 3], np.int32)
    paths = [[0, 1, 2, 3], [0, 1, 2, 3]]
    ps = PathSet.from_lists(paths, query_ids=[0, 1])
    t_q = np.asarray([3, 1], np.int32)  # loose first: tight one is the dup
    scheme, stats = replicate_workload(ps, shard, 4, t_q, prune=True)
    assert stats.failed_paths == 0
    assert is_latency_feasible(ps, scheme, t_q)


# ---------------------------------------------------------------------------
# engine: three-way backend parity for vector-t feasibility / slack
# ---------------------------------------------------------------------------
def test_engine_vector_slack_three_way_parity(rng):
    ps, shard = random_workload(rng, n_paths=180, n_queries=100)
    scheme, _ = replicate_workload(ps, shard, 5, 2)
    t_q = rng.integers(0, 4, ps.n_queries).astype(np.int32)
    slos = [
        t_q,
        SLOSpec(t_q, np.zeros(ps.n_queries, np.int32), (TenantSpec("d", 0),)),
    ]
    ref = None
    for backend in ("reference", "jnp", "pallas"):
        eng = LatencyEngine(scheme, backend=backend)
        for t in slos:
            slack = eng.query_slack(ps, t)
            feas = eng.is_feasible(ps, t)
            if ref is None:
                ref = slack
                # oracle: numpy per-query max vs budget
                want = query_slacks(ps, scheme, t_q)
                assert np.array_equal(slack, want)
            assert np.array_equal(slack, ref), backend
            assert feas == bool((ref >= 0).all()), backend
    # scalar broadcast degenerates to the old behavior
    eng = LatencyEngine(scheme)
    assert eng.is_feasible(ps, 2)
    assert np.array_equal(
        eng.query_slack(ps, 2), query_slacks(ps, scheme, 2)
    )


def test_engine_from_arrays_raw_scheme(rng):
    from repro.engine import RawScheme

    ps, shard = random_workload(rng, n_paths=60)
    scheme, _ = replicate_workload(ps, shard, 5, 1)
    eng = LatencyEngine.from_arrays(scheme.mask, shard)
    assert isinstance(eng.scheme, RawScheme)
    assert np.array_equal(
        eng.path_latencies(ps), LatencyEngine(scheme).path_latencies(ps)
    )
    # RawScheme is a real mutable scheme: add_replicas flips its mask too
    eng.add_replicas(np.asarray([0]), np.asarray([1]))
    assert eng.scheme.mask[0, 1]


# ---------------------------------------------------------------------------
# controller: per-tenant triggers + deterministic arbitration
# ---------------------------------------------------------------------------
def _two_tenant_batch(n_srv=4):
    """Tenant "cheap" violates with short paths, "costly" with long ones.

    Objects are laid out so every path alternates servers (home = id % S),
    making each query of both tenants violate t=0/1 budgets.
    """
    n_obj = 40
    shard = (np.arange(n_obj) % n_srv).astype(np.int32)
    cheap = [[i, i + 1] for i in range(0, 8, 2)]            # 1 hop each
    costly = [[i, i + 1, i + 2, i + 3] for i in range(8, 32, 4)]  # 3 hops
    paths = cheap + costly
    qids = list(range(len(paths)))
    ps = PathSet.from_lists(paths, query_ids=qids)
    tenants = (TenantSpec("cheap", 0), TenantSpec("costly", 1))
    tenant_of = np.asarray(
        [0] * len(cheap) + [1] * len(costly), np.int32
    )
    slo = SLOSpec.from_tenants(tenants, tenant_of)
    return ps, shard, slo, n_obj, n_srv


def test_controller_arbitration_deterministic_winner():
    from repro.core import ReplicationScheme

    ps, shard, slo, n_obj, n_srv = _two_tenant_batch()
    scheme = ReplicationScheme.from_sharding(shard, n_srv)
    cluster = Cluster(scheme)
    ctl = AdaptiveController(
        cluster,
        ControllerConfig(
            tenants=slo.tenants, window=64, min_queries=1,
            capacity=float(n_obj),  # finite headroom => contention
        ),
    )
    report = ctl.observe(ps, slo=slo)
    assert report is not None
    # both tenants violate simultaneously; "cheap" needs fewer marginal
    # bytes per violation, so it deterministically wins the round
    assert report.tenants == ("cheap",)
    assert report.deferred == ("costly",)
    assert report.replicas_added > 0
    assert is_latency_feasible(
        ps, scheme, np.where(np.asarray(slo.tenant_of) == 0, 0, 99)
    )
    # the deferred tenant still violates -> it wins the next round (aging)
    report2 = ctl.observe(ps, slo=slo)
    assert report2 is not None
    assert report2.tenants == ("costly",)
    assert report2.feasible_after
    assert is_latency_feasible(ps, scheme, slo)
    # repeatable: same inputs, same winners
    ps2, shard2, slo2, _, _ = _two_tenant_batch()
    scheme2 = ReplicationScheme.from_sharding(shard2, n_srv)
    ctl2 = AdaptiveController(
        Cluster(scheme2),
        ControllerConfig(
            tenants=slo2.tenants, window=64, min_queries=1,
            capacity=float(n_obj),
        ),
    )
    r1 = ctl2.observe(ps2, slo=slo2)
    assert (r1.tenants, r1.deferred) == (("cheap",), ("costly",))


def test_controller_uncontended_repairs_all_triggered_tenants():
    from repro.core import ReplicationScheme

    ps, shard, slo, _, n_srv = _two_tenant_batch()
    scheme = ReplicationScheme.from_sharding(shard, n_srv)
    ctl = AdaptiveController(
        Cluster(scheme),
        ControllerConfig(tenants=slo.tenants, window=64, min_queries=1),
    )
    report = ctl.observe(ps, slo=slo)
    assert report is not None
    # no capacity/epsilon bound -> nothing to arbitrate: one vector-budget
    # pass repairs both tenants together
    assert set(report.tenants) == {"cheap", "costly"}
    assert report.deferred == ()
    assert report.feasible_after
    assert is_latency_feasible(ps, scheme, slo)


def test_controller_p99_tenant_not_starved_in_arbitration():
    """A tenant that only breaches its wall-clock SLO (no infeasible
    paths, so its repair-cost estimate is inf) must still win a contended
    round via aging; its p99 evidence must survive other tenants' repairs."""
    from repro.core import ReplicationScheme

    n_srv = 4
    n_obj = 40
    shard = (np.arange(n_obj) % n_srv).astype(np.int32)
    tenants = (TenantSpec("a", 0), TenantSpec("p", 5, p99_slo_us=100.0))

    def batch(offset):
        # tenant a: fresh server-crossing pairs each round (violate t=0);
        # tenant p: single-object reads (feasible) but wall-clock slow
        a_paths = [[offset + i, offset + i + 1] for i in range(0, 6, 2)]
        p_paths = [[30 + i] for i in range(4)]
        ps = PathSet.from_lists(
            a_paths + p_paths, query_ids=list(range(len(a_paths) + 4))
        )
        slo = SLOSpec.from_tenants(
            tenants, np.asarray([0] * len(a_paths) + [1] * 4, np.int32)
        )
        lat = np.asarray([10.0] * len(a_paths) + [500.0] * 4)
        return ps, slo, lat

    scheme = ReplicationScheme.from_sharding(shard, n_srv)
    ctl = AdaptiveController(
        Cluster(scheme),
        ControllerConfig(
            tenants=tenants, min_queries=1, capacity=float(n_obj),
        ),
    )
    ps1, slo1, lat1 = batch(0)
    r1 = ctl.observe(ps1, latency_us=lat1, slo=slo1)
    # contended: "a" has a finite marginal-byte score, "p" is inf -> a wins
    assert r1.tenants == ("a",) and r1.deferred == ("p",)
    # "p"'s p99 evidence survived a's repair and its deferral aged: it
    # wins the next contended round outright despite the inf score
    ps2, slo2, lat2 = batch(8)
    r2 = ctl.observe(ps2, latency_us=lat2, slo=slo2)
    assert r2.tenants == ("p",)
    assert "a" in r2.deferred
    assert r2.trigger in ("p99_slo", "feasibility")


def test_controller_unrepairable_window_rearms_on_new_evidence_only():
    """A capacity-blocked (unrepairable) tenant violation must not re-fire
    a no-op repair on every later observe() of other tenants' traffic."""
    from repro.core import ReplicationScheme

    shard = np.asarray([0, 1, 0, 0], np.int32)
    tenants = (TenantSpec("a", 5), TenantSpec("b", 0))
    scheme = ReplicationScheme.from_sharding(shard, 2)
    cluster = Cluster(scheme)
    ctl = AdaptiveController(
        cluster,
        ControllerConfig(
            tenants=tenants, min_queries=1,
            # capacity == current load: every repair candidate is blocked
            # and there are no replicas to evict
            capacity=np.asarray([3.0, 1.0]),
        ),
    )
    bad = PathSet.from_lists([[0, 1]])  # s0 -> s1: violates b's t=0
    slo_b = SLOSpec.from_tenants(tenants, np.asarray([1], np.int32))
    r1 = ctl.observe(bad, slo=slo_b)
    assert r1 is not None and not r1.feasible_after
    assert r1.replicas_added == 0  # capacity-blocked: nothing applied
    # tenant a's traffic keeps flowing; b's stale unrepairable window must
    # not re-trigger a full repair pass on every batch
    ok = PathSet.from_lists([[2], [3]])
    slo_a = SLOSpec.from_tenants(tenants, np.asarray([0, 0], np.int32))
    for _ in range(3):
        assert ctl.observe(ok, slo=slo_a) is None
    # fresh evidence for b re-arms the trigger
    r2 = ctl.observe(bad, slo=slo_b)
    assert r2 is not None and "b" in r2.tenants


def test_controller_per_tenant_windows_and_stats():
    from repro.core import ReplicationScheme

    ps, shard, slo, _, n_srv = _two_tenant_batch()
    scheme = ReplicationScheme.from_sharding(shard, n_srv)
    ctl = AdaptiveController(
        Cluster(scheme),
        # min_queries above either tenant's count: monitor only, no repair
        ControllerConfig(tenants=slo.tenants, window=64, min_queries=1000),
    )
    assert ctl.observe(ps, slo=slo) is None
    stats = ctl.tenant_stats()
    assert set(stats) == {"cheap", "costly"}
    assert stats["cheap"]["violation_frac"] == 1.0
    assert stats["costly"]["violation_frac"] == 1.0
    assert stats["cheap"]["t_q"] == 0 and stats["costly"]["t_q"] == 1


# ---------------------------------------------------------------------------
# priority weights: weighted bytes-per-violation arbitration (PR 4)
# ---------------------------------------------------------------------------
def test_controller_weighted_arbitration_flips_winner():
    """A high enough TenantSpec.weight buys the expensive tenant the
    contended round that cheapest-byte arbitration would give away."""
    from repro.core import ReplicationScheme

    ps, shard, slo, n_obj, n_srv = _two_tenant_batch()
    # same workload, but "costly" now outranks via priority weight
    tenants = (TenantSpec("cheap", 0), TenantSpec("costly", 1, weight=100.0))
    slo = SLOSpec(slo.t_q, slo.tenant_of, tenants)
    scheme = ReplicationScheme.from_sharding(shard, n_srv)
    ctl = AdaptiveController(
        Cluster(scheme),
        ControllerConfig(
            tenants=tenants, window=64, min_queries=1,
            capacity=float(n_obj),
        ),
    )
    report = ctl.observe(ps, slo=slo)
    assert report is not None
    assert report.tenants == ("costly",)
    assert report.deferred == ("cheap",)


def test_controller_low_weight_tenant_cannot_starve():
    """Aging outranks weight: the weight-0.01 tenant deferred on round 1
    wins round 2 outright even though the heavy tenant still violates."""
    from repro.core import ReplicationScheme

    n_srv = 4
    n_obj = 48
    shard = (np.arange(n_obj) % n_srv).astype(np.int32)
    tenants = (TenantSpec("vip", 0, weight=100.0), TenantSpec("lo", 0, weight=0.01))

    def batch(offset):
        # fresh server-crossing pairs each round so BOTH tenants keep
        # violating t=0 until their own repair lands
        vip = [[offset + i, offset + i + 1] for i in range(0, 8, 2)]
        lo = [[24 + offset + i, 24 + offset + i + 1] for i in range(0, 8, 2)]
        ps = PathSet.from_lists(
            vip + lo, query_ids=list(range(len(vip) + len(lo)))
        )
        slo = SLOSpec.from_tenants(
            tenants, np.asarray([0] * len(vip) + [1] * len(lo), np.int32)
        )
        return ps, slo

    scheme = ReplicationScheme.from_sharding(shard, n_srv)
    ctl = AdaptiveController(
        Cluster(scheme),
        ControllerConfig(
            tenants=tenants, window=256, min_queries=1,
            capacity=float(n_obj),
        ),
    )
    ps1, slo1 = batch(0)
    r1 = ctl.observe(ps1, slo=slo1)
    assert r1.tenants == ("vip",) and r1.deferred == ("lo",)
    ps2, slo2 = batch(8)
    r2 = ctl.observe(ps2, slo=slo2)
    # aging: "lo" was deferred on an earlier round, so it wins this one
    # regardless of the 10^4:1 weight ratio
    assert r2.tenants == ("lo",)
    assert "vip" in r2.deferred


def test_tenant_weight_must_be_positive():
    with pytest.raises(ValueError):
        TenantSpec("bad", 1, weight=0.0)


# ---------------------------------------------------------------------------
# per-tenant capacity quotas (PR 8)
# ---------------------------------------------------------------------------
def test_controller_quota_caps_arbitration_until_grace():
    """A tenant over its repair-bytes quota loses contended rounds it
    would otherwise win, until quota_grace deferred steps mark it starving
    and it wins outright — delayed, never denied."""
    from repro.core import ReplicationScheme

    n_srv = 4
    n_obj = 96
    shard = (np.arange(n_obj) % n_srv).astype(np.int32)
    tenants = (TenantSpec("hot", 0), TenantSpec("cold", 0))

    def batch(offset, hot_only=False):
        # hot: fresh 2-object crossings (1 marginal byte per violation);
        # cold: fresh 4-object chains (3 marginal bytes per violation) —
        # so absent a quota, "hot" always wins the contended round
        hot = [[offset + i, offset + i + 1] for i in range(0, 6, 2)]
        cold = [] if hot_only else [
            [48 + offset + i + j for j in range(4)] for i in range(0, 8, 4)
        ]
        ps = PathSet.from_lists(
            hot + cold, query_ids=list(range(len(hot) + len(cold)))
        )
        slo = SLOSpec.from_tenants(
            tenants,
            np.asarray([0] * len(hot) + [1] * len(cold), np.int32),
        )
        return ps, slo

    scheme = ReplicationScheme.from_sharding(shard, n_srv)
    ctl = AdaptiveController(
        Cluster(scheme),
        ControllerConfig(
            tenants=tenants, window=256, min_queries=1,
            capacity=float(n_obj),
            tenant_quota_bytes={"hot": 1.0}, quota_grace=2,
        ),
    )
    # round 1: only "hot" violates (uncontended) -> its repair lands and
    # pushes its cumulative bytes over the 1.0 quota
    ps1, slo1 = batch(0, hot_only=True)
    r1 = ctl.observe(ps1, slo=slo1)
    assert r1 is not None and r1.tenants == ("hot",)
    assert ctl.tenant_stats()["hot"]["repair_bytes"] > 1.0
    assert ctl.tenant_stats()["hot"]["quota_bytes"] == 1.0

    # round 2: contended; "hot" has the cheaper score but is over quota,
    # so "cold" wins the round it would otherwise lose
    ps2, slo2 = batch(8)
    r2 = ctl.observe(ps2, slo=slo2)
    assert r2.tenants == ("cold",) and r2.deferred == ("hot",)

    # round 3: still over quota, deferred only 1 step (< grace): capped
    ps3, slo3 = batch(16)
    r3 = ctl.observe(ps3, slo=slo3)
    assert r3.tenants == ("cold",) and r3.deferred == ("hot",)

    # round 4: deferred 2 steps >= quota_grace -> starving, wins outright
    ps4, slo4 = batch(24)
    r4 = ctl.observe(ps4, slo=slo4)
    assert r4.tenants == ("hot",)
    assert "cold" in r4.deferred


def test_controller_scalar_quota_and_uncapped_default():
    """A scalar quota applies to every tenant; no quota reproduces the
    historical cheapest-byte arbitration bit-for-bit."""
    from repro.core import ReplicationScheme

    ps, shard, slo, n_obj, n_srv = _two_tenant_batch()
    scheme = ReplicationScheme.from_sharding(shard, n_srv)
    ctl = AdaptiveController(
        Cluster(scheme),
        ControllerConfig(
            tenants=slo.tenants, window=64, min_queries=1,
            capacity=float(n_obj), tenant_quota_bytes=1e9,
        ),
    )
    # nobody is over a huge scalar quota: the historical winner holds
    report = ctl.observe(ps, slo=slo)
    assert report.tenants == ("cheap",)
    assert report.deferred == ("costly",)
    assert ctl.tenant_stats()["cheap"]["quota_bytes"] == 1e9
