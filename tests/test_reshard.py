"""§5.4 incremental resharding: RM transfer, drains, repair."""
import numpy as np
import pytest

from repro.core import (
    ReshardingMap,
    apply_reshard,
    drain_server,
    is_latency_feasible,
    repair_paths,
    replicate_workload,
    replicate_workload_exact,
)
from tests.conftest import random_workload


def build(rng, t=1, n_srv=6):
    ps, shard = random_workload(rng, n_obj=150, n_srv=n_srv, n_paths=200)
    scheme, stats = replicate_workload(
        ps, shard.copy(), n_srv, t, track_rm=True)
    rmap = ReshardingMap.from_entries(stats.rm, scheme.shard)
    return ps, scheme, rmap


def test_partition_preserving_drain_stays_feasible(rng):
    """Whole-partition moves (single target): RM transfer alone preserves
    the bound — the setting §5.4's closing argument covers."""
    t = 1
    ps, scheme, rmap = build(rng, t)
    moves, rep = drain_server(scheme, rmap, 3, strategy="single")
    assert is_latency_feasible(ps, scheme, t)
    assert rep.moved_originals > 0


def test_scatter_drain_needs_repair(rng):
    """Scatter moves can split server-local subpaths; repair_paths
    restores the bound incrementally (no full re-analysis)."""
    t = 1
    ps, scheme, rmap = build(rng, t)
    drain_server(scheme, rmap, 3, strategy="round_robin")
    stats = repair_paths(scheme, rmap, ps, t)
    assert stats["failed_paths"] == 0
    assert is_latency_feasible(ps, scheme, t)


def test_refcount_deletion(rng):
    """Replicas whose last association leaves a server are deleted."""
    t = 0
    ps, scheme, rmap = build(rng, t)
    before = scheme.replica_count()
    # move every original off server 0 to server 1
    victims = np.nonzero(scheme.shard == 0)[0]
    moves = {int(u): 1 for u in victims}
    rep = apply_reshard(scheme, rmap, moves)
    # replicas tied to server-0 originals must have moved or been dropped
    assert rep.replicas_transferred + rep.replicas_deleted >= 0
    assert is_latency_feasible(ps, scheme, t)


def test_sequential_drains(rng):
    """Repeated failures: drain two servers one after another."""
    t = 2
    ps, scheme, rmap = build(rng, t)
    drain_server(scheme, rmap, 5, strategy="single")
    assert is_latency_feasible(ps, scheme, t)
    drain_server(scheme, rmap, 4, strategy="single")
    repair_paths(scheme, rmap, ps, t)
    assert is_latency_feasible(ps, scheme, t)


def test_reshard_cost_is_moderate(rng):
    """§6: incremental update moves far less data than re-replicating
    from scratch."""
    t = 1
    ps, scheme, rmap = build(rng, t)
    total_before = scheme.mask.sum()
    _, rep = drain_server(scheme, rmap, 3, strategy="single")
    moved = rep.replicas_transferred + rep.moved_originals
    assert moved < total_before  # strictly incremental
