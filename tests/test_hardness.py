"""Executable check of the Thm 4.5 reduction on small 3-regular graphs:
LS(G) feasibility <=> min-bridge bisection of G within budget K."""
import numpy as np
import pytest

from repro.core import (
    brute_force_feasible,
    brute_force_min_bridge_bisection,
    build_ls_instance,
    is_feasible_ls,
    scheme_from_bisection,
)
from repro.graph import random_regular


@pytest.mark.parametrize("n,seed", [(6, 0), (6, 3), (8, 1)])
def test_reduction_if_direction(n, seed):
    """If G has a bisection with <= K bridges, the constructed scheme is a
    feasible solution of LS(G) (Appendix A.1 'if')."""
    adj = random_regular(n, 3, seed)
    K = brute_force_min_bridge_bisection(adj)
    inst = build_ls_instance(adj, K)
    # recover one optimal bisection by brute force
    import itertools

    best_side = None
    for half in itertools.combinations(range(n), n // 2):
        side = np.ones(n, np.int8)
        side[list(half)] = 0
        bridges = [0, 0]
        for v in range(n):
            if any(side[u] != side[v] for u in adj[v]):
                bridges[side[v]] += 1
        if max(bridges) <= K:
            best_side = side
            break
    assert best_side is not None
    scheme = scheme_from_bisection(inst, adj, best_side)
    assert is_feasible_ls(inst, scheme)


@pytest.mark.parametrize("n,seed", [(6, 0), (6, 5)])
def test_reduction_only_if_direction(n, seed):
    """With K below the true min-bridge value, the bisection-derived
    scheme must violate LS(G)'s capacities (no 'cheap' feasibility)."""
    adj = random_regular(n, 3, seed)
    K = brute_force_min_bridge_bisection(adj)
    if K == 0:
        pytest.skip("graph is disconnectable; no tension")
    inst_tight = build_ls_instance(adj, K - 1)
    # every bisection needs > K-1 bridge replicas on some side -> any
    # bisection-derived scheme violates the tightened capacity
    import itertools

    for half in itertools.combinations(range(n), n // 2):
        side = np.ones(n, np.int8)
        side[list(half)] = 0
        scheme = scheme_from_bisection(inst_tight, adj, side)
        assert not is_feasible_ls(inst_tight, scheme)


def test_budget_characterization():
    adj = random_regular(8, 3, seed=2)
    K = brute_force_min_bridge_bisection(adj)
    inst = build_ls_instance(adj, K)
    assert brute_force_feasible(inst, adj)
    inst2 = build_ls_instance(adj, max(K - 1, 0))
    if K > 0:
        assert not brute_force_feasible(inst2, adj)
