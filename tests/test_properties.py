"""Property-based tests (hypothesis) for the paper's central claims.

  * Thm 5.3 (latency-robustness): after the greedy UPDATE processes a
    path, ARBITRARY later replica additions cannot break that path's
    bound.
  * Thm 5.5: produced schemes are upward replication schemes.
  * Monotonicity: replication cost is non-increasing in t.
  * Feasibility for every prefix of the workload (Alg 1 invariant).
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    PathSet,
    ReplicationScheme,
    is_latency_feasible,
    path_latency_reference,
    replicate_workload,
    replicate_workload_exact,
    server_local_subpaths,
    update_exact,
)


@st.composite
def workloads(draw, max_obj=40, max_srv=6, max_paths=25, max_len=6):
    n_obj = draw(st.integers(4, max_obj))
    n_srv = draw(st.integers(2, max_srv))
    n_paths = draw(st.integers(1, max_paths))
    paths = [
        draw(st.lists(st.integers(0, n_obj - 1), min_size=1,
                      max_size=max_len))
        for _ in range(n_paths)
    ]
    shard = np.asarray(
        [draw(st.integers(0, n_srv - 1)) for _ in range(n_obj)], np.int32)
    t = draw(st.integers(0, 3))
    return paths, shard, n_srv, t


@settings(max_examples=40, deadline=None)
@given(workloads(), st.randoms(use_true_random=False))
def test_latency_robustness_thm_5_3(wl, rnd):
    """Process one path with UPDATE, then add random replicas: the path's
    latency bound must survive (the paper's central correctness claim)."""
    paths, shard, n_srv, t = wl
    scheme = ReplicationScheme.from_sharding(shard, n_srv)
    path = paths[0]
    res = update_exact(scheme, path, t)
    if not res.feasible:
        return
    base = path_latency_reference(path, scheme.mask, shard)
    assert base <= t
    # arbitrary extension: random replica additions
    n_obj = shard.shape[0]
    for _ in range(25):
        v = rnd.randrange(n_obj)
        s = rnd.randrange(n_srv)
        scheme.mask[v, s] = True
        lat = path_latency_reference(path, scheme.mask, shard)
        assert lat <= t, (
            f"robustness violated: path={path}, t={t}, lat={lat}")


@settings(max_examples=30, deadline=None)
@given(workloads())
def test_alg1_prefix_feasibility(wl):
    """After Alg 1 finishes, EVERY path (not just the last) meets t —
    i.e., later UPDATEs never broke earlier paths."""
    paths, shard, n_srv, t = wl
    ps = PathSet.from_lists(paths)
    scheme, stats = replicate_workload_exact(ps, shard, n_srv, t)
    if stats["failed_paths"]:
        return
    for p in paths:
        assert path_latency_reference(p, scheme.mask, shard) <= t


@settings(max_examples=30, deadline=None)
@given(workloads())
def test_upward_replication_thm_5_5(wl):
    """Every replica the algorithm adds is co-located with the original
    copy of a predecessor in some path's server-local subpath structure —
    the executable form of Def 5.4/Thm 5.5."""
    paths, shard, n_srv, t = wl
    ps = PathSet.from_lists(paths)
    scheme, _ = replicate_workload_exact(ps, shard, n_srv, t, prune=False)
    replicas = {(v, s)
                for v, s in zip(*np.nonzero(scheme.mask))
                if shard[v] != s}
    # collect legal (object, server) pairs: v may be replicated at the
    # home of any object that precedes it in some path
    legal = set()
    for p in paths:
        for i, v in enumerate(p):
            for u in p[:i]:
                legal.add((v, int(shard[u])))
    assert replicas <= legal, f"non-upward replicas: {replicas - legal}"


@settings(max_examples=20, deadline=None)
@given(workloads())
def test_cost_monotone_in_t(wl):
    paths, shard, n_srv, _ = wl
    ps = PathSet.from_lists(paths)
    costs = []
    for t in range(0, 4):
        scheme, stats = replicate_workload_exact(ps, shard, n_srv, t)
        costs.append(stats["replicas"])
    assert all(a >= b for a, b in zip(costs, costs[1:])), costs


@settings(max_examples=25, deadline=None)
@given(workloads())
def test_vectorized_always_feasible(wl):
    paths, shard, n_srv, t = wl
    ps = PathSet.from_lists(paths)
    scheme, stats = replicate_workload(ps, shard, n_srv, t, batch_size=8)
    if stats.failed_paths == 0:
        assert is_latency_feasible(ps, scheme, t)


@settings(max_examples=25, deadline=None)
@given(workloads())
def test_pruning_preserves_feasibility(wl):
    """§5.3 pruning: scheme built from the pruned workload is feasible
    for the FULL workload."""
    paths, shard, n_srv, t = wl
    ps = PathSet.from_lists(paths)
    scheme, stats = replicate_workload_exact(ps, shard, n_srv, t, prune=True)
    if stats["failed_paths"] == 0:
        for p in paths:
            assert path_latency_reference(p, scheme.mask, shard) <= t


@settings(max_examples=30, deadline=None)
@given(workloads())
def test_latency_zero_iff_single_site(wl):
    """h(p)=0 under d iff the whole path lives on one server."""
    paths, shard, n_srv, _ = wl
    for p in paths:
        groups = server_local_subpaths(p, shard)
        lat = path_latency_reference(
            p, ReplicationScheme.from_sharding(shard, n_srv).mask, shard)
        assert (lat == 0) == (len(groups) == 1)
