"""Optimizer, schedules, gradient compression, data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data import Prefetcher, lm_batch_fn
from repro.optim import (
    AdamW,
    compress,
    compressed_psum,
    cosine_schedule,
    decompress,
    global_norm,
)


def test_adamw_converges_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0, 1.5])}
    state = opt.init(params)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, _ = opt.update(g, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adamw_grad_clip():
    opt = AdamW(lr=0.0, grad_clip=1.0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    big = {"w": jnp.asarray([100.0, 100.0, 100.0])}
    _, _, gnorm = opt.update(big, state, params)
    assert float(gnorm) > 100  # reported norm is pre-clip


def test_adamw_state_mirrors_params_f32():
    opt = AdamW()
    params = {"a": jnp.zeros((2, 3), jnp.bfloat16)}
    st = opt.init(params)
    assert st.m["a"].dtype == jnp.float32
    assert st.m["a"].shape == (2, 3)


def test_cosine_schedule_shape():
    s = cosine_schedule(1e-3, warmup=10, total=100)
    vals = [float(s(jnp.int32(i))) for i in (0, 5, 10, 50, 100)]
    assert vals[0] == 0.0
    assert abs(vals[1] - 5e-4) < 1e-9   # linear warmup
    assert abs(vals[2] - 1e-3) < 1e-9   # peak
    assert vals[2] > vals[3] > vals[4] > 0  # cosine decay to floor


def test_compression_error_bound(rng):
    x = jnp.asarray(rng.normal(size=(5000,)), jnp.float32)
    c = compress(x)
    err = jnp.abs(decompress(c, x.shape) - x)
    # error bounded by one quantization step of the block max
    blocks = jnp.pad(x, (0, (-x.shape[0]) % 1024)).reshape(-1, 1024)
    step = jnp.abs(blocks).max(axis=1) / 127.0
    assert float(err.max()) <= float(step.max()) * 1.01


def test_compressed_psum_close_to_exact(rng):
    xs = jnp.asarray(rng.normal(size=(4, 3000)), jnp.float32)
    got = jax.vmap(lambda x: compressed_psum(x, "i"), axis_name="i")(xs)
    want = xs.sum(0)
    rel = float(jnp.abs(got[0] - want).max() / jnp.abs(want).max())
    assert rel < 0.05


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(global_norm(t)) == 5.0


def test_prefetcher_deterministic_restart():
    mk = lm_batch_fn(vocab=50, batch=2, seq=8)
    p1 = Prefetcher(mk, start_step=0)
    it = iter(p1)
    s0, b0 = next(it)
    s1, b1 = next(it)
    p1.close()
    # restart from step 1 regenerates the identical batch (restart-exact)
    p2 = Prefetcher(mk, start_step=1)
    s1b, b1b = next(iter(p2))
    p2.close()
    assert s1 == s1b == 1
    assert np.array_equal(b1["tokens"], b1b["tokens"])
