"""Production-scale provisioning suite (PR 6).

Pins the contracts of the fused-megakernel pipeline:

  * **fused parity** — the single-dispatch fused UPDATE step (gate +
    candidate scoring + bit-test + scatter-OR + on-device stats) produces
    the same scheme as the PR-5 separate-dispatch pipeline, bit-identically,
    for every routing policy and for both device backends (jnp | pallas);
    total cost matches to float tolerance (f32 accumulation order differs);
  * **fused prune parity** — the batched independent-group prune makes
    exactly the serial per-candidate decisions;
  * **transfer accounting** — alignment-pad bytes ride ``padded_bytes``,
    never ``h2d_bytes`` (payload stays exact);
  * **streaming** — ``replicate_stream`` over a chunked ``PathStream``
    equals the same chunks through warm-started ``replicate_delta``, with
    peak host residency = one chunk, and streams are single-use;
  * **load-aware provisioning** — a skewed load forecast shifts where the
    queue-aware greedy buys replicas (off the hot server), identically
    fused and separate;
  * **sharding** — the mesh-sharded driver equals the single-device driver
    (skips cleanly with one device; a slow subprocess variant forces 4
    host devices via XLA_FLAGS);
  * **wall-clock guard** — the benchmark's default grid point stays under
    its stated budget (tier-1: catches dispatch-count regressions that
    parity tests cannot see).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from benchmarks.provisioning_scale import DEFAULT_BUDGET_S, default_grid_point
from repro.core.greedy import (
    replicate_delta,
    replicate_stream,
    replicate_workload,
)
from repro.core.paths import PathSet
from repro.core.replication import ReplicationScheme, prune_scheme_replicas
from repro.engine import LatencyEngine, PathStream, TRANSFER, to_device
from repro.engine.sharding import device_count, provisioning_mesh
from tests.conftest import random_workload

POLICIES = [None, "nearest_copy", "queue_aware", "nearest_copy_dp"]


def _case(rng, n_paths=110):
    n_srv = 5
    ps, shard = random_workload(
        rng, n_obj=90, n_srv=n_srv, n_paths=n_paths, max_len=6
    )
    f = rng.uniform(0.5, 2.0, 90).astype(np.float32)
    return ps, shard, n_srv, f


# ---------------------------------------------------------------------------
# fused parity: megakernel pipeline == separate-dispatch pipeline
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", POLICIES)
def test_fused_parity_all_backends(rng, policy):
    ps, shard, n_srv, f = _case(rng)
    sep, sstats = replicate_workload(
        ps, shard, n_srv, t=2, f=f, policy=policy, fused=False
    )
    for backend in ("jnp", "pallas"):
        fus, fstats = replicate_workload(
            ps, shard, n_srv, t=2, f=f, policy=policy,
            policy_backend=backend, fused=True,
        )
        assert np.array_equal(sep.mask, fus.mask), (policy, backend)
        assert np.isclose(sstats.total_cost, fstats.total_cost, rtol=1e-5)
        assert sstats.failed_paths == fstats.failed_paths
        assert sstats.routed_skips == fstats.routed_skips


def test_fused_parity_vector_budgets_and_capacity(rng):
    ps, shard, n_srv, f = _case(rng)
    t_vec = rng.integers(1, 4, ps.n_queries).astype(np.int32)
    for kw in ({"t": t_vec}, {"t": 2, "capacity": 60.0}):
        sep, ss = replicate_workload(
            ps, shard, n_srv, f=f, policy="nearest_copy", fused=False, **kw
        )
        fus, fs = replicate_workload(
            ps, shard, n_srv, f=f, policy="nearest_copy", fused=True, **kw
        )
        assert np.array_equal(sep.mask, fus.mask)
        assert ss.failed_paths == fs.failed_paths


def test_fused_reference_backend_downgrades(rng):
    """fused needs a device backend; reference silently runs separate."""
    ps, shard, n_srv, f = _case(rng, n_paths=40)
    ref, _ = replicate_workload(
        ps, shard, n_srv, t=2, f=f, policy="nearest_copy",
        policy_backend="reference", fused=True,
    )
    sep, _ = replicate_workload(
        ps, shard, n_srv, t=2, f=f, policy="nearest_copy", fused=False
    )
    assert np.array_equal(ref.mask, sep.mask)


# ---------------------------------------------------------------------------
# fused prune: batched independent groups == serial candidate sweep
# ---------------------------------------------------------------------------
def test_fused_prune_decision_identical(rng):
    ps, shard, n_srv, f = _case(rng)
    scheme, _ = replicate_workload(
        ps, shard, n_srv, t=1, f=f, policy="nearest_copy",
        policy_prune=False, fused=True,
    )
    serial = ReplicationScheme(scheme.mask.copy(), shard)
    batched = ReplicationScheme(scheme.mask.copy(), shard)
    n_s, b_s = (
        prune_scheme_replicas(s, ps, 1, policy="nearest_copy", f=f, fused=fu)
        for s, fu in ((serial, False), (batched, True))
    )
    assert np.array_equal(serial.mask, batched.mask)
    assert n_s == b_s  # (dropped, bytes_saved) identical, not just masks


# ---------------------------------------------------------------------------
# transfer accounting: pad bytes are not payload
# ---------------------------------------------------------------------------
def test_transfer_pad_bytes_separate():
    payload = np.zeros((100, 4), np.int32)
    padded = np.zeros((128, 4), np.int32)
    to_device(payload)
    assert TRANSFER.h2d_bytes == payload.nbytes
    assert TRANSFER.padded_bytes == 0
    to_device(padded, payload_bytes=payload.nbytes)
    assert TRANSFER.h2d_bytes == 2 * payload.nbytes
    assert TRANSFER.padded_bytes == padded.nbytes - payload.nbytes
    snap = TRANSFER.snapshot()
    assert snap["padded_bytes"] == 28 * 4 * 4


def test_greedy_batch_pad_rows_not_payload(rng):
    """The driver pads batches to a fixed jit shape; those rows must land
    in padded_bytes, leaving h2d payload == the actual workload bytes."""
    ps, shard, n_srv, f = _case(rng, n_paths=70)  # 70 < batch_size=256
    TRANSFER.reset()
    replicate_workload(ps, shard, n_srv, t=2, f=f, fused=True)
    assert TRANSFER.padded_bytes > 0
    assert TRANSFER.h2d_bytes > 0


# ---------------------------------------------------------------------------
# streaming
# ---------------------------------------------------------------------------
def test_stream_equals_chunked_deltas(rng):
    ps, shard, n_srv, f = _case(rng, n_paths=150)
    chunk = 50
    chunks = [ps.select(np.arange(i, min(i + chunk, ps.n_paths)))
              for i in range(0, ps.n_paths, chunk)]

    scheme_d = ReplicationScheme.from_sharding(shard, n_srv)
    eng = LatencyEngine(scheme_d)
    for c in chunks:
        replicate_delta(c, eng, 2, f=f, policy="nearest_copy", fused=True)

    stream = PathStream(iter(chunks))
    scheme_s, stats = replicate_stream(
        stream, shard, n_srv, t=2, f=f, policy="nearest_copy", fused=True
    )
    assert np.array_equal(scheme_d.mask, scheme_s.mask)
    # per-chunk redundancy pruning dedups before UPDATE; the stream-level
    # counter sees every ingested path
    assert stats.paths_processed <= ps.n_paths
    assert stream.stats.total_paths == ps.n_paths
    assert stats.peak_resident_paths == chunk
    assert stats.peak_resident_paths < ps.n_paths
    assert stream.stats.chunks == len(chunks)


def test_stream_tables_bounded_residency(rng, monkeypatch):
    """Deep-path candidate tables stream in bounded chunks, identically.

    Forcing the stream threshold down makes every budget class take the
    device-assembled construction; the resulting scheme must be
    bit-identical to the host-stacked build, and the StreamStats must
    show peak table residency pinned at the chunk size — strictly below
    the total candidate rows shipped (a genuine stream, not a rename).
    """
    from repro.core import greedy as greedy_mod

    ps, shard, n_srv, f = _case(rng, n_paths=120)
    base, _ = replicate_workload(ps, shard, n_srv, t=2, f=f)
    monkeypatch.setattr(greedy_mod, "_TABLE_STREAM_ROWS", 3)
    forced, fstats = replicate_workload(ps, shard, n_srv, t=2, f=f)
    assert np.array_equal(base.mask, forced.mask)
    assert 0 < fstats.table_peak_rows <= 3
    assert fstats.table_peak_rows < fstats.table_total_rows

    chunk = 40
    chunks = [ps.select(np.arange(i, min(i + chunk, ps.n_paths)))
              for i in range(0, ps.n_paths, chunk)]
    stream = PathStream(iter(chunks))
    _, sstats = replicate_stream(stream, shard, n_srv, t=2, f=f, fused=True)
    assert stream.stats.peak_resident_table_rows == sstats.table_peak_rows
    assert stream.stats.total_table_rows == sstats.table_total_rows
    assert 0 < stream.stats.peak_resident_table_rows <= 3
    assert (
        stream.stats.peak_resident_table_rows
        < stream.stats.total_table_rows
    )


def test_stream_per_chunk_budgets_and_single_use(rng):
    ps, shard, n_srv, f = _case(rng, n_paths=60)
    a, b = ps.select(np.arange(30)), ps.select(np.arange(30, 60))
    stream = PathStream([(a, 1), (b, 3)])
    scheme, stats = replicate_stream(stream, shard, n_srv, f=f, fused=True)
    assert stream.stats.total_paths == 60
    with pytest.raises(RuntimeError, match="single-use"):
        list(stream)
    with pytest.raises(ValueError, match="budget"):
        replicate_stream(PathStream([a]), shard, n_srv)


# ---------------------------------------------------------------------------
# load-aware provisioning (queue_aware + forecast load)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fused", [False, True])
def test_load_forecast_shifts_purchase(fused):
    """Pre-seeded copies of o1/o2 on both s1 and s2; path 0-1-2-3, t=1.

    Load-blind, the walk hops to s1 (home of o1) and finds o2, o3 local —
    served, no purchase.  With s1 forecast hot, the queue-aware walk hops
    to s2 instead and o3 is now a second remote hop — the gate fails and
    the UPDATE, priced under that same walk, buys o3 on s2: the replica
    lands *off* the hot server.
    """
    shard = np.array([0, 1, 2, 1], np.int32)
    ps = PathSet.from_lists([[0, 1, 2, 3]])

    def run(load):
        sch = ReplicationScheme.from_sharding(shard, 3)
        sch.add(np.array([1, 2]), np.array([2, 1]))
        eng = LatencyEngine(sch)
        stats, _ = replicate_delta(
            ps, eng, 1, policy="queue_aware", load=load, fused=fused
        )
        return sch, stats

    cold, cs = run(None)
    hot, hs = run(np.array([0.0, 5.0, 0.0], np.float32))
    assert cs.routed_skips == 1 and cold.mask[3].sum() == 1  # home copy only
    assert hs.routed_skips == 0 and hot.mask[3, 2]
    assert not cold.mask[3, 2]


def test_load_forecast_shifts_workload_level():
    """Same mechanism from a cold start: the first two paths seed
    o1@s2 / o2@s1 (object sizes steer each UPDATE's cheapest candidate),
    which makes the tail path's o1 hop a lookahead *tie* between s1 and
    s2.  Load-blind, the tie resolves to s1 (o1's home), everything is
    local there, and the path is served free.  With s1 forecast hot, the
    queue-aware walk breaks the tie to s2, o3 turns into a second remote
    hop, and the UPDATE — priced under that walk — buys the fix entirely
    on the idle servers: the hot server gains no replicas."""
    shard = np.array([0, 1, 2, 1], np.int32)
    f = np.array([1, 1, 3, 5], np.float32)
    ps = PathSet.from_lists([[2, 1, 2], [3, 2, 3], [0, 1, 2, 3]])
    schemes = {}
    for hot in (False, True):
        load = np.array([0.0, 5.0, 0.0], np.float32) if hot else None
        for fused in (False, True):
            s, st = replicate_workload(
                ps, shard, 3, t=1, f=f, policy="queue_aware", load=load,
                policy_prune=False, fused=fused, batch_size=1,
            )
            schemes[(hot, fused)] = s.mask
            assert st.routed_skips == (0 if hot else 1)
    assert np.array_equal(schemes[(False, False)], schemes[(False, True)])
    assert np.array_equal(schemes[(True, False)], schemes[(True, True)])
    cold, hot = schemes[(False, True)], schemes[(True, True)]
    assert not np.array_equal(cold, hot)
    assert hot[1, 0] and hot[2, 0]           # fix bought on idle s0
    assert np.array_equal(cold[:, 1], hot[:, 1])  # hot s1 gains nothing


# ---------------------------------------------------------------------------
# sharding: mesh == single device
# ---------------------------------------------------------------------------
def test_sharded_equals_single_device(rng):
    if device_count() < 2:
        pytest.skip("single visible device: sharded == single is vacuous")
    ps, shard, n_srv, f = _case(rng)
    single, _ = replicate_workload(
        ps, shard, n_srv, t=2, f=f, policy="nearest_copy", fused=True
    )
    mesh = provisioning_mesh()
    sharded, _ = replicate_workload(
        ps, shard, n_srv, t=2, f=f, policy="nearest_copy", fused=True,
        mesh=mesh,
    )
    assert np.array_equal(single.mask, sharded.mask)


def test_mesh_requires_fused(rng):
    ps, shard, n_srv, f = _case(rng, n_paths=20)
    with pytest.raises(ValueError, match="mesh"):
        replicate_workload(
            ps, shard, n_srv, t=2, f=f, fused=False,
            mesh=provisioning_mesh(),
        )


_SUBPROC = """
import numpy as np
from repro.core.greedy import replicate_workload
from repro.engine.sharding import device_count, provisioning_mesh
from tests.conftest import random_workload

assert device_count() == 4, device_count()
rng = np.random.default_rng(0)
n_srv = 5
ps, shard = random_workload(rng, n_obj=90, n_srv=n_srv, n_paths=110,
                            max_len=6)
f = rng.uniform(0.5, 2.0, 90).astype(np.float32)
for backend in ("jnp", "pallas"):
    single, _ = replicate_workload(ps, shard, n_srv, t=2, f=f,
                                   policy="nearest_copy",
                                   policy_backend=backend, fused=True)
    sharded, _ = replicate_workload(ps, shard, n_srv, t=2, f=f,
                                    policy="nearest_copy",
                                    policy_backend=backend, fused=True,
                                    mesh=provisioning_mesh())
    assert np.array_equal(single.mask, sharded.mask), backend
print("SHARDED_OK")
"""


@pytest.mark.slow
def test_sharded_equals_single_forced_devices():
    """Force 4 host devices in a subprocess and re-check scheme equality
    for both device backends (the in-process test skips on 1-device CI)."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=4"
    ).strip()
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), root, env.get("PYTHONPATH", "")]
    )
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROC], env=env, cwd=root,
        capture_output=True, text=True, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SHARDED_OK" in out.stdout


# ---------------------------------------------------------------------------
# tier-1 wall-clock guard
# ---------------------------------------------------------------------------
def test_default_grid_point_within_budget():
    """The benchmark's default grid point (smoke SNB union, fused arm,
    cold compile) must finish inside its stated budget — a dispatch-count
    regression (e.g. re-introducing per-batch host syncs) blows this long
    before it breaks parity."""
    secs, mask = default_grid_point()
    assert mask.any()
    assert secs < DEFAULT_BUDGET_S, (
        f"default grid point took {secs:.1f}s (budget {DEFAULT_BUDGET_S}s)"
    )
