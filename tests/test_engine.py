"""Three-way backend parity for the unified LatencyEngine.

reference (pure python) vs jnp (packed lax.scan) vs pallas (TPU kernel,
interpret mode on CPU) must agree EXACTLY — integer traversal counts —
over randomized shards/schemes/path lengths, including the documented
edge cases: empty pathsets, length-1 (single-object) paths, and fully
replicated schemes.
"""
import numpy as np
import pytest

from repro.core import PathSet, ReplicationScheme
from repro.engine import LatencyEngine, PackedScheme, pack_bool_mask, unpack_words

BACKENDS = ("reference", "jnp", "pallas")


def _random_case(rng, n_obj, n_srv, n_paths, max_len, extra=0.1):
    shard = rng.integers(0, n_srv, n_obj).astype(np.int32)
    scheme = ReplicationScheme.from_sharding(shard, n_srv)
    k = int(extra * n_obj * n_srv)
    if k:
        scheme.mask[rng.integers(0, n_obj, k), rng.integers(0, n_srv, k)] = True
    paths = [
        rng.integers(0, n_obj, rng.integers(1, max_len + 1)).tolist()
        for _ in range(n_paths)
    ]
    return PathSet.from_lists(paths), scheme


@pytest.mark.parametrize("n_srv", [2, 5, 33, 70])
@pytest.mark.parametrize("n_paths,max_len", [(1, 1), (37, 4), (300, 9)])
def test_three_way_parity(rng, n_srv, n_paths, max_len):
    ps, scheme = _random_case(rng, 150, n_srv, n_paths, max_len)
    outs = {
        b: LatencyEngine(scheme, backend=b, chunk=128).path_latencies(ps)
        for b in BACKENDS
    }
    assert np.array_equal(outs["reference"], outs["jnp"]), n_srv
    assert np.array_equal(outs["reference"], outs["pallas"]), n_srv
    assert outs["jnp"].dtype == np.int32


def test_parity_empty_pathset(rng):
    ps = PathSet.from_lists([])
    _, scheme = _random_case(rng, 20, 3, 1, 2)
    for b in BACKENDS:
        out = LatencyEngine(scheme, backend=b).path_latencies(ps)
        assert out.shape == (0,)


def test_parity_single_object_paths(rng):
    # a one-object path never traverses (h = 0) under every backend
    ps = PathSet.from_lists([[i] for i in range(10)])
    _, scheme = _random_case(rng, 10, 4, 1, 1)
    for b in BACKENDS:
        assert LatencyEngine(scheme, backend=b).path_latencies(ps).sum() == 0


def test_parity_fully_replicated(rng):
    # full replication: everything local after the root -> h = 0 everywhere
    ps, scheme = _random_case(rng, 60, 7, 100, 6)
    scheme.mask[:] = True
    for b in BACKENDS:
        assert LatencyEngine(scheme, backend=b).path_latencies(ps).sum() == 0


def test_parity_under_incremental_updates(rng):
    """Device scatter-OR additions keep all backends in agreement."""
    ps, scheme = _random_case(rng, 80, 5, 120, 6, extra=0.0)
    eng = {b: LatencyEngine(scheme, backend=b) for b in BACKENDS}
    for _ in range(3):
        objs = rng.integers(0, 80, 40)
        srvs = rng.integers(0, 5, 40)
        for e in eng.values():
            e.add_replicas(objs, srvs)
        outs = {b: e.path_latencies(ps) for b, e in eng.items()}
        assert np.array_equal(outs["reference"], outs["jnp"])
        assert np.array_equal(outs["reference"], outs["pallas"])


def test_packed_roundtrip_and_scatter(rng):
    mask = rng.random((50, 40)) < 0.3
    shard = rng.integers(0, 40, 50).astype(np.int32)
    mask[np.arange(50), shard] = True
    packed = PackedScheme.from_mask(mask, shard)
    assert np.array_equal(packed.unpack(), mask)
    assert packed.replica_count() == int(mask.sum()) - 50
    # duplicate pairs + pairs crossing word boundaries
    objs = np.array([0, 0, 0, 3, 3, -1], np.int32)
    srvs = np.array([31, 32, 31, 39, 0, 5], np.int32)
    packed.add(objs, srvs)
    want = mask.copy()
    want[0, 31] = want[0, 32] = want[3, 39] = want[3, 0] = True
    assert np.array_equal(packed.unpack(), want)


def test_pack_unpack_inverse(rng):
    mask = rng.random((33, 70)) < 0.5
    assert np.array_equal(unpack_words(pack_bool_mask(mask), 70), mask)


def test_margin_costs_against_snapshot(rng):
    ps, scheme = _random_case(rng, 40, 6, 10, 4)
    eng = LatencyEngine(scheme)
    f = rng.random(40).astype(np.float32)
    objs = rng.integers(0, 40, (8, 5)).astype(np.int32)
    srvs = rng.integers(0, 6, (8, 5)).astype(np.int32)
    objs[2, 3] = -1  # ignored pair
    got = eng.margin_costs(objs, srvs, f)
    want = np.zeros(8, np.float32)
    for i in range(8):
        for j in range(5):
            v, s = int(objs[i, j]), int(srvs[i, j])
            if v >= 0 and not scheme.mask[v, s]:
                want[i] += f[v]
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_is_feasible_uses_precomputed(rng):
    ps, scheme = _random_case(rng, 60, 4, 50, 5)
    eng = LatencyEngine(scheme)
    pl = eng.path_latencies(ps)
    t = int(pl.max())
    assert eng.is_feasible(ps, t, path_lats=pl)
    assert not eng.is_feasible(ps, t - 1, path_lats=pl)
    # module-level convenience accepts the precomputed array too
    from repro.core import is_latency_feasible

    assert is_latency_feasible(ps, scheme, t, path_lats=pl)


def test_engine_refresh_after_host_mutation(rng):
    ps, scheme = _random_case(rng, 40, 4, 30, 5, extra=0.0)
    eng = LatencyEngine(scheme)
    before = eng.path_latencies(ps)
    scheme.mask[:, :] = True  # direct host mutation bypasses the engine
    eng.refresh()
    assert eng.path_latencies(ps).sum() == 0
    assert before.sum() >= 0
