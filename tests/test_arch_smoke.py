"""Per-architecture smoke tests: reduced config, one real train/serve step
on CPU, output shapes + finiteness (assignment requirement)."""
import jax
import numpy as np
import pytest

from repro.configs import arch_ids, get_arch

ALL_ARCHS = arch_ids()


def test_ten_archs_registered():
    assert len(ALL_ARCHS) == 10
    for expected in ["qwen3-moe-235b-a22b", "deepseek-v2-236b", "qwen2-7b",
                     "h2o-danube-3-4b", "chatglm3-6b", "egnn", "schnet",
                     "graphsage-reddit", "graphcast", "mind"]:
        assert expected in ALL_ARCHS


def _smoke_step(arch):
    bundle = get_arch(arch)
    rng = np.random.default_rng(0)
    batch = bundle.smoke_batch(rng)
    out = bundle.smoke_step()(batch)
    for key, val in out.items():
        arr = np.asarray(val)
        assert np.isfinite(arr).all(), f"{arch}:{key} not finite"
    assert "loss" in out


@pytest.mark.slow
@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_step(arch):
    _smoke_step(arch)


@pytest.mark.parametrize("arch", ["graphsage-reddit"])
def test_arch_smoke_step_fast(arch):
    """One cheap representative real step stays in the fast tier (LM
    forward/backward coverage lives in test_models; full sweep is slow)."""
    _smoke_step(arch)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_abstract_args_no_allocation(arch):
    """Full-size configs build abstract args (ShapeDtypeStructs only)."""
    bundle = get_arch(arch)
    for shape_id in bundle.shape_ids():
        args = bundle.abstract_args(shape_id, multi_pod=False)
        leaves = jax.tree.leaves(args)
        assert leaves, f"{arch}/{shape_id} produced no args"
        for leaf in leaves:
            assert isinstance(leaf, jax.ShapeDtypeStruct), type(leaf)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_shardings_match_args(arch):
    """PartitionSpec trees structurally match the argument trees and all
    sharded dims are divisible by their mesh axes."""
    from jax.sharding import PartitionSpec

    sizes = {"data": 16, "model": 16, "pod": 2}
    for multi_pod in (False, True):
        bundle = get_arch(arch)
        for shape_id in bundle.shape_ids():
            args = bundle.abstract_args(shape_id, multi_pod)
            in_s, out_s = bundle.shardings(shape_id, multi_pod)
            flat_a = jax.tree.leaves(args)
            flat_s = jax.tree.leaves(
                in_s, is_leaf=lambda x: isinstance(x, PartitionSpec))
            assert len(flat_a) == len(flat_s), f"{arch}/{shape_id}"
            for a, s in zip(flat_a, flat_s):
                assert len(s) <= len(a.shape), (arch, shape_id, s, a.shape)
                for dim, axis in zip(a.shape, tuple(s)):
                    if axis is None:
                        continue
                    axes = axis if isinstance(axis, tuple) else (axis,)
                    total = int(np.prod([sizes[ax] for ax in axes]))
                    assert dim % total == 0, (
                        f"{arch}/{shape_id}: dim {dim} not divisible by "
                        f"{axes} ({total})")


def test_lm_long_context_skips_documented():
    for arch in ["qwen3-moe-235b-a22b", "deepseek-v2-236b", "qwen2-7b",
                 "chatglm3-6b"]:
        b = get_arch(arch)
        assert "long_500k" in b.skip_shapes
        assert "long_500k" not in b.cells
    b = get_arch("h2o-danube-3-4b")
    assert "long_500k" in b.cells  # SWA arch runs it


def test_cell_count_totals():
    total = sum(len(get_arch(a).cells) for a in ALL_ARCHS)
    skips = sum(len(get_arch(a).skip_shapes) for a in ALL_ARCHS)
    assert total + skips == 40  # the assignment's 40 cells
    assert total == 36
