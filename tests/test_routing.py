"""RoutingPolicy: the policy-parameterized batched access walk.

Covers the PR-4 contract: three-way backend parity (reference | jnp |
pallas) for every policy, bit-identical ``home_first`` vs the
pre-refactor hardcoded walk on seed workloads, the nearest-copy latency
tightening, the queue-aware hot-replica skip under traffic, and the
threading through executor, simulator and controller.
"""
import numpy as np
import pytest

from repro.core.paths import PathSet
from repro.core.reference import (
    routed_path_latencies_reference,
    routed_trace_reference,
)
from repro.core.replication import ReplicationScheme, prune_scheme_replicas
from repro.engine import (
    BACKENDS,
    HomeFirst,
    LatencyEngine,
    NearestCopy,
    QueueAware,
    pack_bool_mask,
    resolve_policy,
    to_device,
)
from repro.engine import backends
from repro.engine.routing import pick_holder_host

from conftest import random_workload


def _scheme(rng, n_obj, n_srv, density=0.15):
    shard = rng.integers(0, n_srv, n_obj).astype(np.int32)
    mask = np.zeros((n_obj, n_srv), bool)
    mask[np.arange(n_obj), shard] = True
    mask |= rng.random((n_obj, n_srv)) < density
    return mask, shard


# ---------------------------------------------------------------------------
# Policy resolution + the scalar pick oracle
# ---------------------------------------------------------------------------
def test_resolve_policy():
    assert resolve_policy(None) == HomeFirst()
    assert resolve_policy("nearest_copy") == NearestCopy()
    assert resolve_policy("queue_aware").uses_load
    assert resolve_policy(QueueAware()) == QueueAware()
    with pytest.raises(ValueError):
        resolve_policy("round_robin")


def test_pick_holder_host_ordering():
    holders = np.array([False, True, True, True, False])
    # no load: home wins among holders
    assert pick_holder_host(holders, home=2) == 2
    # least-loaded holder wins; home breaks ties
    assert pick_holder_host(holders, 2, load=[0, 9, 9, 1, 0]) == 3
    assert pick_holder_host(holders, 2, load=[0, 5, 5, 5, 0]) == 2
    # lookahead class is preferred even when more loaded
    la = np.array([False, True, False, False, False])
    assert pick_holder_host(holders, 2, load=[0, 9, 1, 1, 0], lookahead=la) == 1
    # empty lookahead intersection falls back to all holders
    la_none = np.array([True, False, False, False, False])
    assert (
        pick_holder_host(holders, 2, load=[0, 9, 1, 9, 0], lookahead=la_none)
        == 2
    )
    assert pick_holder_host(np.zeros(5, bool), 2) == -1


# ---------------------------------------------------------------------------
# Three-way backend parity for the policy walk (counts AND full trace)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", ["home_first", "nearest_copy", "queue_aware"])
def test_three_way_policy_parity(rng, policy):
    ps, shard = random_workload(rng, n_obj=80, n_srv=9, n_paths=70, max_len=6)
    mask = np.zeros((80, 9), bool)
    mask[np.arange(80), shard] = True
    mask |= rng.random((80, 9)) < 0.2
    load = rng.integers(0, 40, 9).astype(np.float64)
    outs, traces = {}, {}
    for b in BACKENDS:
        eng = LatencyEngine.from_arrays(mask, shard, backend=b)
        outs[b] = eng.path_latencies(ps, policy=policy, load=load)
        traces[b] = eng.access_trace(ps, policy=policy, load=load)
    for b in ("jnp", "pallas"):
        np.testing.assert_array_equal(outs["reference"], outs[b])
        np.testing.assert_array_equal(traces["reference"][0], traces[b][0])
        np.testing.assert_array_equal(traces["reference"][1], traces[b][1])


@pytest.mark.parametrize("policy", ["nearest_copy", "queue_aware"])
def test_policy_walk_single_position_paths(rng, policy):
    """max_len == 1 pathsets (zero scan steps) must not break the walk.

    Regression: the lookahead rows were built one element too long for
    L == 1, crashing the jnp scan with a leading-axis mismatch.
    """
    mask, shard = _scheme(rng, 20, 4)
    ps = PathSet.from_lists([[0], [5], [7]])
    load = np.arange(4, dtype=np.float64)
    outs = {}
    for b in BACKENDS:
        eng = LatencyEngine.from_arrays(mask, shard, backend=b)
        outs[b] = eng.path_latencies(ps, policy=policy, load=load)
        srv, loc = eng.access_trace(ps, policy=policy, load=load)
        assert loc.all()
    for b in ("jnp", "pallas"):
        np.testing.assert_array_equal(outs["reference"], outs[b])
    assert outs["jnp"].tolist() == [0, 0, 0]


def test_generic_walk_home_first_bit_identical(rng):
    """The policy-parameterized impl reproduces the pre-refactor walk.

    ``_routed_trace_impl(home_first=True)`` vs the legacy
    ``_access_trace_impl`` (the exact pre-refactor scan), on a seed-style
    workload — servers and local arrays must be bit-identical.
    """
    ps, shard = random_workload(rng, n_obj=100, n_srv=7, n_paths=120)
    mask, shard = _scheme(rng, 100, 7)
    words = np.concatenate(
        [pack_bool_mask(mask), np.zeros((1, 1), np.uint32)], axis=0
    )
    objects = to_device(np.asarray(ps.objects, np.int32))
    lengths = to_device(np.asarray(ps.lengths, np.int32))
    w = to_device(words)
    home = to_device(shard)
    start = backends._root_home(objects, home)
    legacy = backends._access_trace_impl(objects, lengths, w, home, start)
    routed = backends._routed_trace_impl(
        objects, lengths, w, home, start,
        backends._load_vector(None, words), home_first=True, lookahead=False,
    )
    np.testing.assert_array_equal(np.asarray(legacy[0]), np.asarray(routed[0]))
    np.testing.assert_array_equal(np.asarray(legacy[1]), np.asarray(routed[1]))


def test_home_first_policy_matches_default_engine(rng):
    """engine.path_latencies(policy='home_first') == the unpoliced call."""
    ps, shard = random_workload(rng)
    mask, shard = _scheme(rng, 120, 5)
    for b in BACKENDS:
        eng = LatencyEngine.from_arrays(mask, shard, backend=b)
        np.testing.assert_array_equal(
            eng.path_latencies(ps), eng.path_latencies(ps, policy="home_first")
        )


def test_nearest_copy_tightens_latency(rng):
    """h under nearest_copy <= h under home_first wherever replicas help.

    Constructed case: path [a, b, c]; server 2 holds copies of both b and
    c; homes are 0, 1, 2 for a, b, c.  home_first hops to 1 then to 2
    (h=2); nearest_copy's lookahead hops straight to 2 where c is local
    (h=1).
    """
    shard = np.array([0, 1, 2], np.int32)
    mask = np.zeros((3, 3), bool)
    mask[np.arange(3), shard] = True
    mask[1, 2] = True  # replica of b at server 2
    ps = PathSet.from_lists([[0, 1, 2]])
    for b in BACKENDS:
        eng = LatencyEngine.from_arrays(mask, shard, backend=b)
        assert eng.path_latencies(ps)[0] == 2
        assert eng.path_latencies(ps, policy="nearest_copy")[0] == 1
    # and the tightening is visible to is_feasible
    eng = LatencyEngine.from_arrays(mask, shard)
    assert not eng.is_feasible(ps, 1)
    assert eng.is_feasible(ps, 1, policy="nearest_copy")


def test_nearest_copy_statistically_tighter(rng):
    """On random replicated schemes the nearest-copy total h is <= and
    typically < the home-first total (it never needs to do worse than
    following the home, which is always a holder)."""
    ps, _ = random_workload(rng, n_obj=150, n_srv=8, n_paths=200)
    mask, shard = _scheme(rng, 150, 8, density=0.25)
    eng = LatencyEngine.from_arrays(mask, shard)
    hf = eng.path_latencies(ps)
    nc = eng.path_latencies(ps, policy="nearest_copy")
    assert nc.sum() < hf.sum()


def test_queue_aware_skips_hot_replica_in_walk():
    """Under load the batched walk routes the hop around the hot holder.

    Object 1 has copies at servers 1 and 2; its home (1) is hot.  The
    walk starts at 0 (no local copy) and must hop: queue_aware picks 2,
    home_first and an unloaded nearest_copy stick with 1.
    """
    shard = np.array([0, 1], np.int32)
    mask = np.zeros((2, 3), bool)
    mask[np.arange(2), shard] = True
    mask[1, 2] = True
    ps = PathSet.from_lists([[0, 1]])
    load = np.array([0.0, 50.0, 1.0])
    for b in BACKENDS:
        eng = LatencyEngine.from_arrays(mask, shard, backend=b)
        srv_hf, _ = eng.access_trace(ps)
        srv_nc, _ = eng.access_trace(ps, policy="nearest_copy", load=load)
        srv_qa, _ = eng.access_trace(ps, policy="queue_aware", load=load)
        assert srv_hf[0, 1] == 1
        assert srv_nc[0, 1] == 1  # nearest_copy ignores load: home wins
        assert srv_qa[0, 1] == 2  # queue_aware skips the hot home


def test_routed_walk_respects_liveness():
    """Dead servers' copies are invisible; no alive copy -> server -1."""
    from repro.distsys.executor import trace_paths

    shard = np.array([0, 1], np.int32)
    mask = np.zeros((2, 3), bool)
    mask[np.arange(2), shard] = True
    mask[1, 2] = True
    scheme = ReplicationScheme(mask, shard)
    ps = PathSet.from_lists([[0, 1]])
    alive = np.array([True, False, True])
    for pol in ("home_first", "nearest_copy", "queue_aware"):
        servers, local = trace_paths(ps, scheme, alive, policy=pol)
        assert servers[0, 1] == 2  # fail-over to the surviving copy
    servers, _ = trace_paths(
        ps, scheme, np.array([True, False, False]), policy="nearest_copy"
    )
    assert servers[0, 1] == -1


# ---------------------------------------------------------------------------
# Threading: executor, simulator, controller, prune
# ---------------------------------------------------------------------------
def test_executor_policy_param(rng):
    from repro.distsys import Cluster, execute_workload

    ps, shard = random_workload(rng, n_obj=100, n_srv=6, n_paths=100)
    mask, shard = _scheme(rng, 100, 6, density=0.3)
    scheme = ReplicationScheme(mask, shard)
    rep_hf = execute_workload(Cluster(scheme), ps, seed=1)
    rep_nc = execute_workload(Cluster(scheme), ps, seed=1, policy="nearest_copy")
    assert rep_nc.query_traversals.sum() <= rep_hf.query_traversals.sum()


def test_simulator_policy_and_reroute(rng):
    from repro.distsys import Cluster
    from repro.serve import simulate

    ps, shard = random_workload(
        rng, n_obj=100, n_srv=5, n_paths=150, n_queries=60
    )
    mask, shard = _scheme(rng, 100, 5, density=0.3)
    cluster = Cluster(ReplicationScheme(mask, shard))
    rep = simulate(
        cluster, ps, rate_qps=3e4, seed=2, policy="queue_aware",
        reroute_every=16,
    )
    assert rep.policy == "queue_aware"
    assert rep.reroutes >= 1
    assert (rep.latency_us > 0).all()
    with pytest.raises(ValueError):
        from repro.distsys.router import Router

        simulate(
            cluster, ps, router=Router(cluster.scheme, "replica_lb"),
            policy="queue_aware", reroute_every=8,
        )


def test_prune_scheme_replicas_keeps_feasibility(rng):
    ps, shard = random_workload(rng, n_obj=60, n_srv=5, n_paths=60)
    mask, shard = _scheme(rng, 60, 5, density=0.4)
    scheme = ReplicationScheme(mask.copy(), shard)
    eng = LatencyEngine(scheme)
    t = int(eng.path_latencies(ps, policy="nearest_copy").max())
    before = scheme.replica_count()
    n, saved = prune_scheme_replicas(scheme, ps, t, policy="nearest_copy")
    assert n > 0 and saved > 0
    assert scheme.replica_count() == before - n
    assert LatencyEngine(scheme).is_feasible(ps, t, policy="nearest_copy")


def test_reference_routed_trace_contract(rng):
    """Oracle shape/locality contract (position 0 local, padding carries)."""
    mask, shard = _scheme(rng, 30, 4)
    ps = PathSet.from_lists([[0, 1, 2], [5]])
    servers, local = routed_trace_reference(
        np.asarray(ps.objects), np.asarray(ps.lengths), mask, shard,
        policy="nearest_copy",
    )
    assert local[0, 0] and local[1, 0]
    assert servers[1, 1] == servers[1, 0]  # padding carries the last server
    h = routed_path_latencies_reference(
        np.asarray(ps.objects), np.asarray(ps.lengths), mask, shard,
        policy="nearest_copy",
    )
    assert h[1] == 0
